//! `DceContext` — the driver handle (SparkContext analog).
//!
//! Owns the executor pool, shuffle manager, object cache, the tiered
//! store hookup, and the DAG scheduler that turns an RDD lineage graph
//! into shuffle-bounded stages of retryable tasks.

use anyhow::Result;
use std::any::Any;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::executor::{ExecutorPool, TaskContext};
use super::rdd::{Data, Rdd, RddNode, ShuffleDep};
use crate::config::PlatformConfig;
use crate::metrics::MetricsRegistry;
use crate::storage::{DfsStore, EvictionPolicy, TieredStore, UnderStore};
use crate::trace;

/// Deserialised-object partition cache (Spark MEMORY_ONLY analog).
#[derive(Default)]
pub struct CacheManager {
    map: Mutex<HashMap<(usize, usize), Arc<dyn Any + Send + Sync>>>,
}

impl CacheManager {
    pub fn get<T: Data>(&self, rdd: usize, part: usize) -> Option<Arc<Vec<T>>> {
        self.map
            .lock()
            .unwrap()
            .get(&(rdd, part))
            .and_then(|a| a.clone().downcast::<Vec<T>>().ok())
    }

    pub fn put<T: Data>(&self, rdd: usize, part: usize, data: Arc<Vec<T>>) {
        self.map.lock().unwrap().insert((rdd, part), data);
    }

    pub fn evict_rdd(&self, rdd: usize) {
        self.map.lock().unwrap().retain(|(r, _), _| *r != rdd);
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

pub(crate) struct CtxInner {
    pub config: PlatformConfig,
    pub pool: ExecutorPool,
    pub shuffle: Arc<super::shuffle::ShuffleManager>,
    pub cache: CacheManager,
    pub store: Arc<TieredStore>,
    pub dfs: Arc<DfsStore>,
    pub metrics: MetricsRegistry,
    next_id: AtomicUsize,
    pub fail_injector: Mutex<Option<Arc<dyn Fn(&TaskContext) -> Result<()> + Send + Sync>>>,
}

/// The driver context. Clone freely — all clones share the cluster.
#[derive(Clone)]
pub struct DceContext {
    pub(crate) inner: Arc<CtxInner>,
}

impl DceContext {
    pub fn new(config: PlatformConfig) -> Result<Self> {
        let metrics = MetricsRegistry::new();
        let under =
            UnderStore::temp("dce", config.storage.dfs.clone(), config.storage.model_devices)?;
        let store = TieredStore::new(&config.storage, under, EvictionPolicy::Lru, metrics.clone());
        let dfs = DfsStore::new(
            config.storage.dfs.clone(),
            config.storage.model_devices,
            metrics.clone(),
        )?;
        let shuffle = super::shuffle::ShuffleManager::with_config(
            metrics.clone(),
            config.engine.shuffle_shards,
            config.engine.shuffle_single_lock,
            config.engine.shuffle_spill_budget,
        );
        shuffle.set_spill_store(store.clone());
        // Unified infrastructure: shuffle traffic rides the tiered store's
        // MEM device; the staged baseline charges the DFS device instead.
        if config.engine.shuffle_through_tiered {
            shuffle.set_transport(Some(Arc::new(crate::storage::DeviceModel::new(
                config.storage.mem.clone(),
                config.storage.model_devices,
            ))));
        } else {
            shuffle.set_transport(Some(Arc::new(crate::storage::DeviceModel::new(
                config.storage.dfs.clone(),
                config.storage.model_devices,
            ))));
        }
        let pool = ExecutorPool::new(config.cluster.total_cores());
        Ok(Self {
            inner: Arc::new(CtxInner {
                pool,
                shuffle,
                cache: CacheManager::default(),
                store,
                dfs,
                metrics,
                next_id: AtomicUsize::new(0),
                fail_injector: Mutex::new(None),
                config,
            }),
        })
    }

    /// Small local context for tests.
    pub fn local() -> Result<Self> {
        Self::new(PlatformConfig::test())
    }

    pub fn config(&self) -> &PlatformConfig {
        &self.inner.config
    }

    pub fn metrics(&self) -> &MetricsRegistry {
        &self.inner.metrics
    }

    pub fn store(&self) -> &Arc<TieredStore> {
        &self.inner.store
    }

    pub fn dfs(&self) -> &Arc<DfsStore> {
        &self.inner.dfs
    }

    pub fn default_parallelism(&self) -> usize {
        self.inner.config.engine.default_parallelism
    }

    /// Total work-steal count across the executor pool — the raw feed
    /// for an `obs` sampler probe (`dce.executor.steals` rate).
    pub fn executor_steals(&self) -> u64 {
        self.inner.pool.steals()
    }

    pub(crate) fn next_id(&self) -> usize {
        self.inner.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Install (or clear) a fault injector applied to every task.
    pub fn set_fail_injector(
        &self,
        f: Option<Arc<dyn Fn(&TaskContext) -> Result<()> + Send + Sync>>,
    ) {
        *self.inner.fail_injector.lock().unwrap() = f;
    }

    /// Distribute a local collection over `parts` partitions.
    pub fn parallelize<T: Data>(&self, data: Vec<T>, parts: usize) -> Rdd<T> {
        Rdd::parallelize(self.clone(), data, parts.max(1))
    }

    /// `0..n` as an RDD.
    pub fn range(&self, n: u64, parts: usize) -> Rdd<u64> {
        self.parallelize((0..n).collect(), parts)
    }

    /// Drop all cached partitions and shuffle state (including any
    /// bucket blobs spilled to the tiered store).
    pub fn gc(&self) {
        self.inner.cache.map.lock().unwrap().clear();
        self.inner.shuffle.clear_all();
    }

    // ------------------------------------------------------------------
    // DAG scheduler
    // ------------------------------------------------------------------

    /// Transitive shuffle dependencies, parents before children.
    fn topo_shuffle_deps(root: &[Arc<dyn ShuffleDep>]) -> Vec<Arc<dyn ShuffleDep>> {
        let mut order: Vec<Arc<dyn ShuffleDep>> = Vec::new();
        let mut seen: HashSet<usize> = HashSet::new();
        fn visit(
            dep: &Arc<dyn ShuffleDep>,
            seen: &mut HashSet<usize>,
            order: &mut Vec<Arc<dyn ShuffleDep>>,
        ) {
            if !seen.insert(dep.shuffle_id()) {
                return;
            }
            for p in dep.parents() {
                visit(&p, seen, order);
            }
            order.push(dep.clone());
        }
        for d in root {
            visit(d, &mut seen, &mut order);
        }
        order
    }

    fn task_ctx(&self, stage: &str, partition: usize, attempt: usize) -> TaskContext {
        TaskContext {
            stage: stage.to_string(),
            partition,
            attempt,
            metrics: self.inner.metrics.clone(),
            fail_injector: self.inner.fail_injector.lock().unwrap().clone(),
        }
    }

    /// Run a full job: materialise every pending shuffle stage in
    /// dependency order, then run the final stage through `action`.
    pub(crate) fn run_job<T: Data, U: Send + 'static>(
        &self,
        node: Arc<dyn RddNode<T>>,
        action: Arc<dyn Fn(usize, Vec<T>) -> Result<U> + Send + Sync>,
    ) -> Result<Vec<U>> {
        let job_start = Instant::now();
        // Nests under whatever span is current on the driving thread
        // (a `job.shard` attempt or the job root, typically).
        let mut jsp = trace::span("dce.job", trace::Category::Compute);
        jsp.arg("parts", node.num_partitions() as u64);
        let retries = self.inner.config.engine.max_task_retries;
        for dep in Self::topo_shuffle_deps(&node.shuffle_deps()) {
            if self.inner.shuffle.is_complete(dep.shuffle_id()) {
                continue;
            }
            let stage_name = format!("shuffle-{}", dep.shuffle_id());
            let stage_start = Instant::now();
            let mut ssp = trace::span("dce.shuffle", trace::Category::Shuffle);
            ssp.arg("shuffle", dep.shuffle_id() as u64)
                .arg("maps", dep.num_maps() as u64);
            // Hints read bucket ownership from the parent shuffles,
            // which the topo order has already materialised.
            let hints: Vec<Option<usize>> =
                (0..dep.num_maps()).map(|m| dep.placement_hint(m)).collect();
            let tasks: Vec<Arc<dyn Fn(usize) -> Result<()> + Send + Sync>> = (0..dep.num_maps())
                .map(|m| {
                    let dep = dep.clone();
                    let ctx = self.clone();
                    let stage = stage_name.clone();
                    let hint = hints[m];
                    let f: Arc<dyn Fn(usize) -> Result<()> + Send + Sync> =
                        Arc::new(move |attempt| {
                            let tc = ctx.task_ctx(&stage, m, attempt);
                            tc.check_failure()?;
                            if let Some(h) = hint {
                                ctx.inner
                                    .shuffle
                                    .record_affinity(ctx.inner.pool.current_worker() == Some(h));
                            }
                            dep.run_map_task(m, &tc)
                        });
                    f
                })
                .collect();
            self.inner.pool.run_tasks_hinted(
                tasks,
                &hints,
                retries,
                "dce.task",
                trace::Category::Shuffle,
            )?;
            self.inner.shuffle.mark_complete(dep.shuffle_id());
            drop(ssp);
            self.inner
                .metrics
                .histogram("dce.stage.map")
                .record(stage_start.elapsed());
        }
        // Final (result) stage: shuffle readers hint at the worker
        // holding the plurality of their input bytes (every dep is
        // materialised by now, so ownership is fully known).
        let stage_start = Instant::now();
        let parts = node.num_partitions();
        let hints: Vec<Option<usize>> = (0..parts).map(|p| node.placement_hint(p)).collect();
        let tasks: Vec<Arc<dyn Fn(usize) -> Result<U> + Send + Sync>> = (0..parts)
            .map(|p| {
                let node = node.clone();
                let ctx = self.clone();
                let action = action.clone();
                let hint = hints[p];
                let f: Arc<dyn Fn(usize) -> Result<U> + Send + Sync> = Arc::new(move |attempt| {
                    let tc = ctx.task_ctx("result", p, attempt);
                    tc.check_failure()?;
                    if let Some(h) = hint {
                        ctx.inner
                            .shuffle
                            .record_affinity(ctx.inner.pool.current_worker() == Some(h));
                    }
                    let items = node.compute(p, &tc)?;
                    action(p, items)
                });
                f
            })
            .collect();
        let out = self.inner.pool.run_tasks_hinted(
            tasks,
            &hints,
            retries,
            "dce.task",
            trace::Category::Compute,
        )?;
        self.inner
            .metrics
            .histogram("dce.stage.result")
            .record(stage_start.elapsed());
        self.inner.metrics.histogram("dce.job").record(job_start.elapsed());
        self.inner.metrics.counter("dce.jobs").inc();
        Ok(out)
    }
}
