//! Wide (shuffle) operations on key/value RDDs: combine/reduce/group by
//! key, join. These cut stages: the map side hash-partitions and
//! locally combines into the [`ShuffleManager`]; the reduce side merges
//! buckets. Missing buckets (executor loss) are regenerated from lineage
//! by re-running the owning map task.

use anyhow::Result;
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::Arc;

use super::context::DceContext;
use super::executor::TaskContext;
use super::rdd::{Data, Rdd, RddNode, ShuffleDep};
use super::shuffle::ShuffleManager;

/// Stable hash partitioner.
pub fn partition_of<K: Hash>(key: &K, parts: usize) -> usize {
    let mut h = DefaultHasher::new();
    key.hash(&mut h);
    (h.finish() % parts as u64) as usize
}

fn est_bytes<T>(n: usize) -> u64 {
    (n * std::mem::size_of::<T>()) as u64 + 16
}

/// Typed shuffle dependency: map side of combine_by_key.
struct ShuffleDepImpl<K: Data + Hash + Eq, V: Data, C: Data> {
    shuffle_id: usize,
    parent: Arc<dyn RddNode<(K, V)>>,
    num_reduce: usize,
    mgr: Arc<ShuffleManager>,
    create: Arc<dyn Fn(V) -> C + Send + Sync>,
    merge_v: Arc<dyn Fn(C, V) -> C + Send + Sync>,
    /// Combiner-merge — the associative op the manager's map-side
    /// combine applies per bucket (and the reduce side across buckets).
    merge_c: Arc<dyn Fn(C, C) -> C + Send + Sync>,
}

impl<K: Data + Hash + Eq, V: Data, C: Data> ShuffleDep for ShuffleDepImpl<K, V, C> {
    fn shuffle_id(&self) -> usize {
        self.shuffle_id
    }

    fn num_maps(&self) -> usize {
        self.parent.num_partitions()
    }

    fn run_map_task(&self, map_part: usize, tc: &TaskContext) -> Result<()> {
        let items = self.parent.compute(map_part, tc)?;
        if self.mgr.combine_in_manager() {
            // Sharded plane: hand the manager raw created combiners per
            // bucket and let it merge with `merge_c` before insertion
            // (tracked by `dce.shuffle.combine_*`). Equivalent to the
            // fold below because `merge_c(create(v1), create(v2)) ==
            // merge_v(create(v1), v2)` — the combineByKey contract.
            let mut buckets: Vec<Vec<(K, C)>> =
                (0..self.num_reduce).map(|_| Vec::new()).collect();
            for (k, v) in items {
                let b = partition_of(&k, self.num_reduce);
                let c = (self.create)(v);
                buckets[b].push((k, c));
            }
            for (r, raw) in buckets.into_iter().enumerate() {
                self.mgr.put_bucket_combined(
                    self.shuffle_id,
                    map_part,
                    r,
                    raw,
                    &*self.merge_c,
                    est_bytes::<(K, C)>,
                );
            }
            return Ok(());
        }
        // Baseline arm: the pre-PR-10 map-task-local fold.
        let mut buckets: Vec<HashMap<K, C>> =
            (0..self.num_reduce).map(|_| HashMap::new()).collect();
        for (k, v) in items {
            let b = partition_of(&k, self.num_reduce);
            match buckets[b].remove(&k) {
                Some(c) => {
                    buckets[b].insert(k, (self.merge_v)(c, v));
                }
                None => {
                    let c = (self.create)(v);
                    buckets[b].insert(k, c);
                }
            }
        }
        for (r, bucket) in buckets.into_iter().enumerate() {
            let data: Vec<(K, C)> = bucket.into_iter().collect();
            let bytes = est_bytes::<(K, C)>(data.len());
            self.mgr.put_bucket(self.shuffle_id, map_part, r, data, bytes);
        }
        Ok(())
    }

    fn parents(&self) -> Vec<Arc<dyn ShuffleDep>> {
        self.parent.shuffle_deps()
    }

    fn placement_hint(&self, map_part: usize) -> Option<usize> {
        // Map tasks inherit locality from their (possibly shuffled) input.
        self.parent.placement_hint(map_part)
    }
}

/// Reduce side: merges per-map combined buckets.
struct ShuffledNode<K: Data + Hash + Eq, V: Data, C: Data> {
    dep: Arc<ShuffleDepImpl<K, V, C>>,
}

impl<K: Data + Hash + Eq, V: Data, C: Data> ShuffledNode<K, V, C> {
    /// Regenerate any missing map buckets for this reduce partition
    /// (lineage-based shuffle recovery after a lost executor / retry).
    fn ensure_buckets(&self, reduce_part: usize, tc: &TaskContext) -> Result<()> {
        for m in 0..self.dep.num_maps() {
            if !self.dep.mgr.has_bucket(self.dep.shuffle_id, m, reduce_part) {
                tc.metrics.counter("dce.shuffle.regenerated_maps").inc();
                self.dep.run_map_task(m, tc)?;
            }
        }
        Ok(())
    }
}

impl<K: Data + Hash + Eq, V: Data, C: Data> RddNode<(K, C)> for ShuffledNode<K, V, C> {
    fn num_partitions(&self) -> usize {
        self.dep.num_reduce
    }

    fn compute(&self, part: usize, tc: &TaskContext) -> Result<Vec<(K, C)>> {
        self.ensure_buckets(part, tc)?;
        let buckets: Vec<Vec<(K, C)>> =
            self.dep.mgr.take_buckets(self.dep.shuffle_id, self.dep.num_maps(), part)?;
        let mut merged: HashMap<K, C> = HashMap::new();
        for bucket in buckets {
            for (k, c) in bucket {
                match merged.remove(&k) {
                    Some(prev) => {
                        merged.insert(k, (self.dep.merge_c)(prev, c));
                    }
                    None => {
                        merged.insert(k, c);
                    }
                }
            }
        }
        Ok(merged.into_iter().collect())
    }

    fn shuffle_deps(&self) -> Vec<Arc<dyn ShuffleDep>> {
        vec![self.dep.clone()]
    }

    fn placement_hint(&self, part: usize) -> Option<usize> {
        self.dep.mgr.preferred_worker(self.dep.shuffle_id, self.dep.num_maps(), part)
    }
}

/// Two-sided shuffle for joins (cogroup).
struct CoGroupNode<K: Data + Hash + Eq, V: Data, W: Data> {
    left: Arc<ShuffleDepImpl<K, V, Vec<V>>>,
    right: Arc<ShuffleDepImpl<K, W, Vec<W>>>,
}

impl<K: Data + Hash + Eq, V: Data, W: Data> RddNode<(K, (Vec<V>, Vec<W>))>
    for CoGroupNode<K, V, W>
{
    fn num_partitions(&self) -> usize {
        self.left.num_reduce
    }

    fn compute(&self, part: usize, tc: &TaskContext) -> Result<Vec<(K, (Vec<V>, Vec<W>))>> {
        for m in 0..self.left.num_maps() {
            if !self.left.mgr.has_bucket(self.left.shuffle_id, m, part) {
                self.left.run_map_task(m, tc)?;
            }
        }
        for m in 0..self.right.num_maps() {
            if !self.right.mgr.has_bucket(self.right.shuffle_id, m, part) {
                self.right.run_map_task(m, tc)?;
            }
        }
        let mut merged: HashMap<K, (Vec<V>, Vec<W>)> = HashMap::new();
        let lbuckets: Vec<Vec<(K, Vec<V>)>> =
            self.left.mgr.take_buckets(self.left.shuffle_id, self.left.num_maps(), part)?;
        for bucket in lbuckets {
            for (k, mut vs) in bucket {
                merged.entry(k).or_default().0.append(&mut vs);
            }
        }
        let rbuckets: Vec<Vec<(K, Vec<W>)>> =
            self.right.mgr.take_buckets(self.right.shuffle_id, self.right.num_maps(), part)?;
        for bucket in rbuckets {
            for (k, mut ws) in bucket {
                merged.entry(k).or_default().1.append(&mut ws);
            }
        }
        Ok(merged.into_iter().collect())
    }

    fn shuffle_deps(&self) -> Vec<Arc<dyn ShuffleDep>> {
        vec![self.left.clone(), self.right.clone()]
    }

    fn placement_hint(&self, part: usize) -> Option<usize> {
        self.left
            .mgr
            .preferred_worker(self.left.shuffle_id, self.left.num_maps(), part)
            .or_else(|| {
                self.right.mgr.preferred_worker(
                    self.right.shuffle_id,
                    self.right.num_maps(),
                    part,
                )
            })
    }
}

fn make_dep<K: Data + Hash + Eq, V: Data, C: Data>(
    ctx: &DceContext,
    parent: Arc<dyn RddNode<(K, V)>>,
    num_reduce: usize,
    create: Arc<dyn Fn(V) -> C + Send + Sync>,
    merge_v: Arc<dyn Fn(C, V) -> C + Send + Sync>,
    merge_c: Arc<dyn Fn(C, C) -> C + Send + Sync>,
) -> Arc<ShuffleDepImpl<K, V, C>> {
    Arc::new(ShuffleDepImpl {
        shuffle_id: ctx.next_id(),
        parent,
        num_reduce,
        mgr: ctx.inner.shuffle.clone(),
        create,
        merge_v,
        merge_c,
    })
}

impl<K: Data + Hash + Eq, V: Data> Rdd<(K, V)> {
    /// The general combiner (Spark's combineByKey): map-side combine,
    /// hash shuffle, reduce-side merge.
    pub fn combine_by_key<C: Data>(
        &self,
        create: impl Fn(V) -> C + Send + Sync + 'static,
        merge_v: impl Fn(C, V) -> C + Send + Sync + 'static,
        merge_c: impl Fn(C, C) -> C + Send + Sync + 'static,
        num_parts: usize,
    ) -> Rdd<(K, C)> {
        let dep = make_dep(
            &self.ctx,
            self.node.clone(),
            num_parts.max(1),
            Arc::new(create),
            Arc::new(merge_v),
            Arc::new(merge_c),
        );
        Rdd::from_node(self.ctx.clone(), Arc::new(ShuffledNode { dep }))
    }

    pub fn reduce_by_key(
        &self,
        f: impl Fn(V, V) -> V + Send + Sync + 'static,
        num_parts: usize,
    ) -> Rdd<(K, V)> {
        let f = Arc::new(f);
        let f2 = f.clone();
        self.combine_by_key(
            |v| v,
            move |c, v| f(c, v),
            move |a, b| f2(a, b),
            num_parts,
        )
    }

    pub fn group_by_key(&self, num_parts: usize) -> Rdd<(K, Vec<V>)> {
        self.combine_by_key(
            |v| vec![v],
            |mut c, v| {
                c.push(v);
                c
            },
            |mut a, mut b| {
                a.append(&mut b);
                a
            },
            num_parts,
        )
    }

    pub fn count_by_key(&self) -> Result<HashMap<K, u64>> {
        let pairs = self
            .map(|(k, _)| (k, 1u64))
            .reduce_by_key(|a, b| a + b, self.ctx.default_parallelism())
            .collect()?;
        Ok(pairs.into_iter().collect())
    }

    /// Inner hash join.
    pub fn join<W: Data>(&self, other: &Rdd<(K, W)>, num_parts: usize) -> Rdd<(K, (V, W))> {
        let left = make_dep(
            &self.ctx,
            self.node.clone(),
            num_parts.max(1),
            Arc::new(|v: V| vec![v]),
            Arc::new(|mut c: Vec<V>, v| {
                c.push(v);
                c
            }),
            Arc::new(|mut a: Vec<V>, mut b: Vec<V>| {
                a.append(&mut b);
                a
            }),
        );
        let right = make_dep(
            &self.ctx,
            other.node.clone(),
            num_parts.max(1),
            Arc::new(|w: W| vec![w]),
            Arc::new(|mut c: Vec<W>, w| {
                c.push(w);
                c
            }),
            Arc::new(|mut a: Vec<W>, mut b: Vec<W>| {
                a.append(&mut b);
                a
            }),
        );
        let cogrouped: Rdd<(K, (Vec<V>, Vec<W>))> =
            Rdd::from_node(self.ctx.clone(), Arc::new(CoGroupNode { left, right }));
        cogrouped.flat_map(|(k, (vs, ws))| {
            let mut out = Vec::with_capacity(vs.len() * ws.len());
            for v in &vs {
                for w in &ws {
                    out.push((k.clone(), (v.clone(), w.clone())));
                }
            }
            out
        })
    }
}

impl<K: Data + Hash + Eq + Ord, V: Data> Rdd<(K, V)> {
    /// Collect sorted by key (driver-side sort; range-partitioned
    /// distributed sorts are out of scope for the workloads here).
    pub fn collect_sorted_by_key(&self) -> Result<Vec<(K, V)>> {
        let mut out = self.collect()?;
        out.sort_by(|a, b| a.0.cmp(&b.0));
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> DceContext {
        DceContext::local().unwrap()
    }

    #[test]
    fn reduce_by_key_sums() {
        let c = ctx();
        let pairs: Vec<(u32, u64)> = (0..100).map(|i| (i % 5, i as u64)).collect();
        let mut got = c
            .parallelize(pairs, 6)
            .reduce_by_key(|a, b| a + b, 3)
            .collect_sorted_by_key()
            .unwrap();
        got.sort();
        let mut want: HashMap<u32, u64> = HashMap::new();
        for i in 0..100u64 {
            *want.entry((i % 5) as u32).or_default() += i;
        }
        let mut want: Vec<(u32, u64)> = want.into_iter().collect();
        want.sort();
        assert_eq!(got, want);
    }

    #[test]
    fn group_by_key_collects_all_values() {
        let c = ctx();
        let pairs = vec![("a", 1), ("b", 2), ("a", 3), ("b", 4), ("a", 5)];
        let groups = c.parallelize(pairs, 3).group_by_key(2).collect().unwrap();
        let m: HashMap<&str, Vec<i32>> = groups
            .into_iter()
            .map(|(k, mut v)| {
                v.sort();
                (k, v)
            })
            .collect();
        assert_eq!(m["a"], vec![1, 3, 5]);
        assert_eq!(m["b"], vec![2, 4]);
    }

    #[test]
    fn count_by_key_matches() {
        let c = ctx();
        let pairs: Vec<(u8, ())> = (0..30).map(|i| ((i % 3) as u8, ())).collect();
        let counts = c.parallelize(pairs, 4).count_by_key().unwrap();
        assert_eq!(counts[&0], 10);
        assert_eq!(counts[&1], 10);
        assert_eq!(counts[&2], 10);
    }

    #[test]
    fn join_inner_semantics() {
        let c = ctx();
        let users = c.parallelize(vec![(1u32, "ann"), (2, "bob"), (3, "cat")], 2);
        let carts = c.parallelize(vec![(1u32, 10.0f64), (1, 20.0), (3, 30.0), (9, 99.0)], 3);
        let mut joined = users.join(&carts, 2).collect().unwrap();
        joined.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(
            joined,
            vec![(1, ("ann", 10.0)), (1, ("ann", 20.0)), (3, ("cat", 30.0))]
        );
    }

    #[test]
    fn multi_stage_shuffle_chain() {
        // shuffle -> map -> shuffle again (tests transitive stage order).
        let c = ctx();
        let pairs: Vec<(u32, u64)> = (0..200).map(|i| (i % 10, 1u64)).collect();
        let out = c
            .parallelize(pairs, 5)
            .reduce_by_key(|a, b| a + b, 4) // (k, 20) x10
            .map(|(k, n)| (k % 2, n))
            .reduce_by_key(|a, b| a + b, 2) // (0, 100), (1, 100)
            .collect_sorted_by_key()
            .unwrap();
        assert_eq!(out, vec![(0, 100), (1, 100)]);
    }

    #[test]
    fn shuffle_survives_injected_reduce_failure() {
        let c = ctx();
        use std::sync::atomic::{AtomicU32, Ordering};
        let hits = Arc::new(AtomicU32::new(0));
        let h2 = hits.clone();
        c.set_fail_injector(Some(Arc::new(move |tc| {
            if tc.stage == "result" && tc.attempt == 0 && tc.partition == 0 {
                h2.fetch_add(1, Ordering::SeqCst);
                anyhow::bail!("reducer crash")
            }
            Ok(())
        })));
        let pairs: Vec<(u32, u64)> = (0..50).map(|i| (i % 4, 1)).collect();
        let out = c
            .parallelize(pairs, 4)
            .reduce_by_key(|a, b| a + b, 2)
            .collect()
            .unwrap();
        c.set_fail_injector(None);
        assert_eq!(out.iter().map(|(_, n)| n).sum::<u64>(), 50);
        assert_eq!(hits.load(Ordering::SeqCst), 1, "injector fired exactly once");
    }

    #[test]
    fn sharded_combine_matches_baseline_arm_end_to_end() {
        // The E22 correctness contract: the same wide stages through
        // the sharded+combine plane and through the `--baseline`
        // single-lock arm are bit-identical after key-sorting.
        use crate::config::PlatformConfig;
        let fast = ctx();
        let mut cfg = PlatformConfig::test();
        cfg.engine.shuffle_single_lock = true;
        let slow = DceContext::new(cfg).unwrap();
        let pairs: Vec<(u32, u64)> = (0..400).map(|i| (i % 13, (i * 7) as u64)).collect();
        let run = |c: &DceContext| {
            let rdd = c.parallelize(pairs.clone(), 6);
            let reduced = rdd.reduce_by_key(|a, b| a + b, 4).collect_sorted_by_key().unwrap();
            let grouped: Vec<(u32, Vec<u64>)> = rdd
                .group_by_key(3)
                .map(|(k, mut v)| {
                    v.sort();
                    (k, v)
                })
                .collect_sorted_by_key()
                .unwrap();
            let other = c.parallelize(vec![(1u32, "x"), (5, "y"), (12, "z")], 2);
            let mut joined = rdd.join(&other, 3).collect().unwrap();
            joined.sort_by(|a, b| a.partial_cmp(b).unwrap());
            (reduced, grouped, joined)
        };
        assert_eq!(run(&fast), run(&slow));
        // Only the sharded arm combines in the manager...
        assert!(fast.metrics().counter("dce.shuffle.combine_in").get() > 0);
        assert_eq!(slow.metrics().counter("dce.shuffle.combine_in").get(), 0);
        // ...and it must actually have merged (13 keys from 400 rows).
        assert!(
            fast.metrics().gauge("dce.shuffle.combine_ratio").get() > 100,
            "combine never reduced anything"
        );
    }

    #[test]
    fn shuffle_jobs_report_affinity_placement() {
        // Reduce tasks are hinted at bucket owners; whatever worker
        // they actually land on, every hinted task must be counted.
        let c = ctx();
        let pairs: Vec<(u32, u64)> = (0..200).map(|i| (i % 8, 1)).collect();
        let out =
            c.parallelize(pairs, 4).reduce_by_key(|a, b| a + b, 4).collect_sorted_by_key().unwrap();
        assert_eq!(out.iter().map(|(_, n)| n).sum::<u64>(), 200);
        let hits = c.metrics().counter("dce.shuffle.affinity_hits").get();
        let misses = c.metrics().counter("dce.shuffle.affinity_misses").get();
        assert!(hits + misses >= 1, "no hinted task was ever dispatched");
    }

    #[test]
    fn spilling_context_still_computes_correctly() {
        // A tiny resident budget forces most buckets through the
        // store; results must not change and the blobs must be GC'd.
        use crate::config::PlatformConfig;
        let mut cfg = PlatformConfig::test();
        cfg.engine.shuffle_spill_budget = 64; // bytes — nearly everything spills
        let c = DceContext::new(cfg).unwrap();
        let pairs: Vec<(u32, u64)> = (0..300).map(|i| (i % 11, i as u64)).collect();
        let got =
            c.parallelize(pairs, 5).reduce_by_key(|a, b| a + b, 4).collect_sorted_by_key().unwrap();
        let mut want: HashMap<u32, u64> = HashMap::new();
        for i in 0..300u64 {
            *want.entry((i % 11) as u32).or_default() += i;
        }
        let mut want: Vec<(u32, u64)> = want.into_iter().collect();
        want.sort();
        assert_eq!(got, want);
        assert!(
            c.metrics().counter("dce.shuffle.spilled_buckets").get() > 0,
            "budget of 64B must have spilled"
        );
        c.gc();
        assert!(c.store().keys_with_prefix("shuf/").is_empty(), "gc left spilled blobs");
    }

    #[test]
    fn partitioner_is_stable() {
        for parts in [1usize, 2, 7] {
            for k in 0..100u64 {
                assert_eq!(partition_of(&k, parts), partition_of(&k, parts));
                assert!(partition_of(&k, parts) < parts);
            }
        }
    }
}
