//! BinPipeRDD (paper section 3.1): binary records through Spark-style
//! partitions and OS pipes.
//!
//! The paper's problem: Spark consumes line-delimited text, but
//! simulation replays need "multimedia binary data recorded by ROS".
//! Their answer — and ours — is a length-framed binary record codec plus
//! a pipe operator: each partition is encoded to one byte stream, fed to
//! a native user-logic process over a real Unix pipe, and the process's
//! framed output stream becomes the next RDD's partition ("launched ROS
//! and Spark independently ... having Spark communicate with ROS nodes
//! through Linux pipes").
//!
//! Frame format (little-endian):
//! `"BPR1" | u32 record_count | { u32 len | len bytes }*`

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::process::{Command, Stdio};
use std::sync::Arc;

use super::rdd::Rdd;
use crate::storage::TieredStore;

pub const MAGIC: &[u8; 4] = b"BPR1";

/// Encode records into one framed byte stream.
pub fn encode_records(records: &[Vec<u8>]) -> Vec<u8> {
    let payload: usize = records.iter().map(|r| r.len() + 4).sum();
    let mut out = Vec::with_capacity(8 + payload);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(records.len() as u32).to_le_bytes());
    for r in records {
        out.extend_from_slice(&(r.len() as u32).to_le_bytes());
        out.extend_from_slice(r);
    }
    out
}

/// Decode a framed byte stream back into records.
pub fn decode_stream(bytes: &[u8]) -> Result<Vec<Vec<u8>>> {
    if bytes.len() < 8 {
        bail!("BinPipe stream truncated: {} bytes", bytes.len());
    }
    if &bytes[..4] != MAGIC {
        bail!("BinPipe bad magic {:?}", &bytes[..4]);
    }
    let count = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
    let mut records = Vec::with_capacity(count);
    let mut off = 8usize;
    for i in 0..count {
        if off + 4 > bytes.len() {
            bail!("BinPipe record {i}: length header past end");
        }
        let len =
            u32::from_le_bytes([bytes[off], bytes[off + 1], bytes[off + 2], bytes[off + 3]])
                as usize;
        off += 4;
        if off + len > bytes.len() {
            bail!("BinPipe record {i}: {len} bytes past end");
        }
        records.push(bytes[off..off + len].to_vec());
        off += len;
    }
    if off != bytes.len() {
        bail!("BinPipe trailing garbage: {} bytes", bytes.len() - off);
    }
    Ok(records)
}

/// Streaming reader used by pipe-worker children (stdin side).
pub fn read_stream(r: &mut impl Read) -> Result<Vec<Vec<u8>>> {
    let mut all = Vec::new();
    r.read_to_end(&mut all).context("reading BinPipe stream")?;
    decode_stream(&all)
}

/// Streaming writer used by pipe-worker children (stdout side).
pub fn write_stream(w: &mut impl Write, records: &[Vec<u8>]) -> Result<()> {
    w.write_all(&encode_records(records)).context("writing BinPipe stream")?;
    w.flush()?;
    Ok(())
}

/// Binary-record operations on `Rdd<Vec<u8>>`.
pub trait BinaryRddExt {
    /// Pipe every partition through a child process over real OS pipes.
    /// The child reads one framed stream on stdin and must write one
    /// framed stream on stdout.
    fn pipe_through(&self, cmd: Vec<String>) -> Rdd<Vec<u8>>;

    /// Persist partitions as framed blocks in the tiered store under
    /// `prefix` (with lineage registered for recovery), returning a new
    /// RDD that reads from the store.
    fn persist_tiered(&self, prefix: &str) -> Result<Rdd<Vec<u8>>>;

    /// Total payload bytes.
    fn total_bytes(&self) -> Result<u64>;
}

impl BinaryRddExt for Rdd<Vec<u8>> {
    fn pipe_through(&self, cmd: Vec<String>) -> Rdd<Vec<u8>> {
        self.map_partitions(move |part, records| {
            if cmd.is_empty() {
                bail!("pipe_through: empty command");
            }
            let mut child = Command::new(&cmd[0])
                .args(&cmd[1..])
                .stdin(Stdio::piped())
                .stdout(Stdio::piped())
                .stderr(Stdio::inherit())
                .spawn()
                .with_context(|| format!("spawning pipe worker {:?}", cmd[0]))?;
            let mut stdin = child.stdin.take().expect("piped stdin");
            let encoded = encode_records(&records);
            drop(records);
            // Writer thread: the kernel pipe buffer is small, so writing
            // and reading must overlap or large partitions deadlock.
            let writer = std::thread::spawn(move || -> Result<()> {
                stdin.write_all(&encoded)?;
                Ok(())
            });
            let mut out_bytes = Vec::new();
            child
                .stdout
                .take()
                .expect("piped stdout")
                .read_to_end(&mut out_bytes)
                .context("reading pipe worker output")?;
            writer
                .join()
                .map_err(|_| anyhow::anyhow!("pipe writer panicked"))?
                .context("writing to pipe worker")?;
            let status = child.wait()?;
            if !status.success() {
                bail!("pipe worker exited with {status} on partition {part}");
            }
            decode_stream(&out_bytes)
        })
    }

    fn persist_tiered(&self, prefix: &str) -> Result<Rdd<Vec<u8>>> {
        let store: Arc<TieredStore> = self.context().store().clone();
        let prefix = prefix.to_string();
        let store2 = store.clone();
        let prefix2 = prefix.clone();
        // Write every partition now (one job), registering lineage.
        let keys: Vec<String> = self
            .context()
            .run_job(
                self.node.clone(),
                Arc::new(move |part, records: Vec<Vec<u8>>| {
                    let key = format!("{prefix2}/part-{part:05}");
                    store2.put(&key, encode_records(&records))?;
                    Ok(key)
                }),
            )?;
        // Reader RDD: partitions come back from the tiered store.
        let ctx = self.context().clone();
        let parts = keys.len();
        let keys = Arc::new(keys);
        let rdd = ctx
            .range(parts as u64, parts)
            .map_partitions(move |part, _ids: Vec<u64>| {
                let blob = store.get(&keys[part])?;
                decode_stream(&blob)
            });
        let _ = prefix;
        Ok(rdd)
    }

    fn total_bytes(&self) -> Result<u64> {
        let sizes = self
            .map(|r| r.len() as u64)
            .reduce(|a, b| a + b)?;
        Ok(sizes.unwrap_or(0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dce::DceContext;

    #[test]
    fn encode_decode_roundtrip() {
        let records = vec![b"hello".to_vec(), Vec::new(), vec![0u8, 255, 7], vec![1u8; 10_000]];
        let stream = encode_records(&records);
        assert_eq!(decode_stream(&stream).unwrap(), records);
    }

    #[test]
    fn decode_rejects_corruption() {
        let records = vec![b"data".to_vec()];
        let mut stream = encode_records(&records);
        // bad magic
        let mut bad = stream.clone();
        bad[0] = b'X';
        assert!(decode_stream(&bad).is_err());
        // truncated
        stream.truncate(stream.len() - 1);
        assert!(decode_stream(&stream).is_err());
        // trailing garbage
        let mut extra = encode_records(&records);
        extra.push(0);
        assert!(decode_stream(&extra).is_err());
        // too short
        assert!(decode_stream(&[1, 2, 3]).is_err());
    }

    #[test]
    fn binary_records_of_any_value_survive() {
        // The paper's point: any byte value may appear in key/value data
        // (no delimiter assumptions). Include every byte 0..=255.
        let rec: Vec<u8> = (0..=255u8).collect();
        let records = vec![rec.clone(), rec];
        let got = decode_stream(&encode_records(&records)).unwrap();
        assert_eq!(got, records);
    }

    #[test]
    fn pipe_through_cat_is_identity() {
        let c = DceContext::local().unwrap();
        let records: Vec<Vec<u8>> = (0..64u32).map(|i| i.to_le_bytes().to_vec()).collect();
        let rdd = c.parallelize(records.clone(), 4);
        let out = rdd.pipe_through(vec!["cat".into()]).collect().unwrap();
        assert_eq!(out, records);
    }

    #[test]
    fn pipe_through_large_partition_no_deadlock() {
        // > pipe buffer (64KiB) to prove reader/writer overlap works.
        let c = DceContext::local().unwrap();
        let records: Vec<Vec<u8>> = (0..40).map(|i| vec![i as u8; 64 * 1024]).collect();
        let rdd = c.parallelize(records.clone(), 2);
        let out = rdd.pipe_through(vec!["cat".into()]).collect().unwrap();
        assert_eq!(out.len(), 40);
        assert_eq!(out, records);
    }

    #[test]
    fn pipe_through_failing_command_errors() {
        let c = DceContext::local().unwrap();
        let rdd = c.parallelize(vec![b"x".to_vec()], 1);
        assert!(rdd.pipe_through(vec!["false".into()]).collect().is_err());
        assert!(rdd
            .pipe_through(vec!["/nonexistent/binary".into()])
            .collect()
            .is_err());
    }

    #[test]
    fn persist_tiered_roundtrip_and_lineage() {
        let c = DceContext::local().unwrap();
        let records: Vec<Vec<u8>> = (0..10u8).map(|i| vec![i; 100]).collect();
        let rdd = c.parallelize(records.clone(), 3);
        let persisted = rdd.persist_tiered("test/bin").unwrap();
        let mut got = persisted.collect().unwrap();
        got.sort();
        let mut want = records;
        want.sort();
        assert_eq!(got, want);
        // Blocks really are in the store.
        assert!(c.store().contains("test/bin/part-00000"));
    }

    #[test]
    fn total_bytes_sums_payload() {
        let c = DceContext::local().unwrap();
        let rdd = c.parallelize(vec![vec![0u8; 10], vec![0u8; 30]], 2);
        assert_eq!(rdd.total_bytes().unwrap(), 40);
    }
}
