//! Discrete-event virtual-time cluster simulation.
//!
//! Reproduces the paper's datacenter-scale scaling results (Fig 6's
//! 2,000→10,000 cores; the 1→8-node replay scaling; Fig 9's GPU
//! scaling) by running the *real* stage/task structure against measured
//! per-task costs on a simulated cluster: a min-heap of core free-times,
//! FIFO task placement, modelled network/disk transfer, per-task
//! scheduler overhead, and lognormal straggler jitter. Every bench that
//! uses this mode labels its rows `virtual-time` (see DESIGN.md §6).

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::Duration;

use crate::util::Rng;

/// Simulated cluster shape + device models.
#[derive(Debug, Clone)]
pub struct SimCluster {
    pub nodes: usize,
    pub cores_per_node: usize,
    /// Per-core effective remote-read bandwidth (bytes/s).
    pub net_bps: f64,
    /// Per-core effective local-disk bandwidth (bytes/s).
    pub disk_bps: f64,
    /// Fixed scheduler/dispatch overhead per task.
    pub sched_overhead: Duration,
    /// Coefficient of variation of task-duration jitter (stragglers).
    pub straggler_cv: f64,
    pub seed: u64,
}

impl SimCluster {
    pub fn with_cores(total_cores: usize) -> Self {
        Self {
            nodes: total_cores.div_ceil(16).max(1),
            cores_per_node: 16.min(total_cores.max(1)),
            net_bps: 1.2e9,
            disk_bps: 400e6,
            sched_overhead: Duration::from_millis(5),
            straggler_cv: 0.15,
            seed: 42,
        }
    }

    pub fn total_cores(&self) -> usize {
        self.nodes * self.cores_per_node
    }
}

/// One simulated task.
#[derive(Debug, Clone)]
pub struct SimTask {
    /// Pure compute time (from a calibrated [`super::costmodel::CostModel`]).
    pub compute: Duration,
    pub input_bytes: u64,
    /// Remote (network) input vs node-local disk.
    pub remote_read: bool,
    pub output_bytes: u64,
}

impl SimTask {
    pub fn compute_only(compute: Duration) -> Self {
        Self { compute, input_bytes: 0, remote_read: false, output_bytes: 0 }
    }
}

/// A barrier-separated stage (Spark stage semantics).
#[derive(Debug, Clone)]
pub struct SimStage {
    pub name: String,
    pub tasks: Vec<SimTask>,
}

/// A job: stages run in order with a full barrier between them.
#[derive(Debug, Clone, Default)]
pub struct SimJob {
    pub stages: Vec<SimStage>,
}

impl SimJob {
    pub fn single_stage(name: &str, tasks: Vec<SimTask>) -> Self {
        Self { stages: vec![SimStage { name: name.to_string(), tasks }] }
    }
}

/// Simulation outcome.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub makespan: Duration,
    pub stage_times: Vec<(String, Duration)>,
    /// Sum of all task durations (core-busy time).
    pub core_busy: Duration,
    /// core_busy / (cores x makespan).
    pub utilization: f64,
}

/// Run the discrete-event simulation.
pub fn simulate(cluster: &SimCluster, job: &SimJob) -> SimReport {
    let cores = cluster.total_cores();
    let mut rng = Rng::new(cluster.seed);
    // Lognormal jitter with unit mean.
    let cv = cluster.straggler_cv.max(0.0);
    let sigma = (1.0 + cv * cv).ln().sqrt();
    let mut stage_times = Vec::with_capacity(job.stages.len());
    let mut clock = Duration::ZERO;
    let mut core_busy = Duration::ZERO;
    for stage in &job.stages {
        // Min-heap of core free times (u128 ns), all reset to the stage
        // start (barrier semantics).
        let mut heap: BinaryHeap<Reverse<u128>> = (0..cores)
            .map(|_| Reverse(clock.as_nanos()))
            .collect();
        let mut stage_end = clock;
        for task in &stage.tasks {
            let Reverse(free_at) = heap.pop().expect("cores > 0");
            let io_bps = if task.remote_read { cluster.net_bps } else { cluster.disk_bps };
            let io = Duration::from_secs_f64(
                task.input_bytes as f64 / io_bps + task.output_bytes as f64 / cluster.disk_bps,
            );
            let jitter = if sigma > 0.0 {
                (sigma * rng.normal() - sigma * sigma / 2.0).exp()
            } else {
                1.0
            };
            let dur = cluster.sched_overhead + task.compute.mul_f64(jitter) + io;
            core_busy += dur;
            let end = free_at + dur.as_nanos();
            if end > stage_end.as_nanos() {
                stage_end = Duration::from_nanos(end as u64);
            }
            heap.push(Reverse(end));
        }
        stage_times.push((stage.name.clone(), stage_end - clock));
        clock = stage_end; // barrier
    }
    let utilization = if clock.is_zero() {
        0.0
    } else {
        core_busy.as_secs_f64() / (cores as f64 * clock.as_secs_f64())
    };
    SimReport { makespan: clock, stage_times, core_busy, utilization }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform_job(tasks: usize, ms: u64) -> SimJob {
        SimJob::single_stage(
            "s",
            (0..tasks)
                .map(|_| SimTask::compute_only(Duration::from_millis(ms)))
                .collect(),
        )
    }

    fn cluster(cores: usize) -> SimCluster {
        SimCluster {
            nodes: 1,
            cores_per_node: cores,
            net_bps: 1e9,
            disk_bps: 5e8,
            sched_overhead: Duration::ZERO,
            straggler_cv: 0.0,
            seed: 1,
        }
    }

    #[test]
    fn perfect_scaling_without_jitter() {
        let job = uniform_job(1000, 10);
        let t1 = simulate(&cluster(10), &job).makespan;
        let t2 = simulate(&cluster(20), &job).makespan;
        let ratio = t1.as_secs_f64() / t2.as_secs_f64();
        assert!((ratio - 2.0).abs() < 0.05, "ratio {ratio}");
    }

    #[test]
    fn makespan_is_critical_path_for_few_tasks() {
        // 3 tasks on 8 cores: makespan == longest task.
        let mut job = uniform_job(3, 10);
        job.stages[0].tasks[1].compute = Duration::from_millis(50);
        let r = simulate(&cluster(8), &job);
        assert_eq!(r.makespan, Duration::from_millis(50));
        assert!(r.utilization < 0.2);
    }

    #[test]
    fn barrier_between_stages() {
        let job = SimJob {
            stages: vec![
                SimStage { name: "a".into(), tasks: uniform_job(4, 10).stages[0].tasks.clone() },
                SimStage { name: "b".into(), tasks: uniform_job(4, 20).stages[0].tasks.clone() },
            ],
        };
        let r = simulate(&cluster(4), &job);
        assert_eq!(r.makespan, Duration::from_millis(30));
        assert_eq!(r.stage_times[0].1, Duration::from_millis(10));
        assert_eq!(r.stage_times[1].1, Duration::from_millis(20));
    }

    #[test]
    fn io_adds_transfer_time() {
        let task = SimTask {
            compute: Duration::from_millis(10),
            input_bytes: 500_000_000, // 0.5s at 1e9 net
            remote_read: true,
            output_bytes: 0,
        };
        let r = simulate(&cluster(1), &SimJob::single_stage("io", vec![task]));
        assert!(r.makespan >= Duration::from_millis(510), "{:?}", r.makespan);
        // Local disk is slower in this config: 1s.
        let task_local = SimTask { remote_read: false, ..SimTask::compute_only(Duration::ZERO) };
        let mut t = task_local;
        t.input_bytes = 500_000_000;
        let r2 = simulate(&cluster(1), &SimJob::single_stage("io", vec![t]));
        assert!(r2.makespan >= Duration::from_millis(990));
    }

    #[test]
    fn deterministic_given_seed() {
        let mut c = cluster(7);
        c.straggler_cv = 0.3;
        let job = uniform_job(200, 5);
        let a = simulate(&c, &job).makespan;
        let b = simulate(&c, &job).makespan;
        assert_eq!(a, b);
    }

    #[test]
    fn stragglers_hurt_tail() {
        let job = uniform_job(64, 10);
        let mut c = cluster(64);
        let clean = simulate(&c, &job).makespan;
        c.straggler_cv = 0.5;
        let jittered = simulate(&c, &job).makespan;
        assert!(jittered > clean, "{jittered:?} <= {clean:?}");
    }

    #[test]
    fn utilization_bounded() {
        let r = simulate(&cluster(8), &uniform_job(1000, 1));
        assert!(r.utilization > 0.9 && r.utilization <= 1.0 + 1e-9, "{}", r.utilization);
    }
}
