//! The distributed compute engine (Spark analog, paper section 2.1).
//!
//! * [`rdd`] / [`pair`] — typed, lineage-tracked RDDs with pipelined
//!   narrow stages and hash-shuffled wide stages.
//! * [`context`] — driver context + DAG scheduler (stages cut at shuffle
//!   boundaries, retryable tasks, fault injection).
//! * [`executor`] — the worker pool.
//! * [`shuffle`] — the shuffle data plane with transport-device
//!   accounting (tiered store vs DFS).
//! * [`binpipe`] — BinPipeRDD (paper section 3.1): framed binary records
//!   and pipe-through-child-process execution.
//! * [`simclock`] / [`costmodel`] — discrete-event virtual-time cluster
//!   simulation driven by measured task costs, for the paper's
//!   datacenter-scale scaling figures.

pub mod binpipe;
pub mod context;
pub mod costmodel;
pub mod executor;
pub mod pair;
pub mod rdd;
pub mod shuffle;
pub mod simclock;

pub use binpipe::{decode_stream, encode_records, BinaryRddExt};
pub use context::{CacheManager, DceContext};
pub use costmodel::{measure_per_item_cost, CostModel};
pub use executor::{ExecutorPool, TaskContext};
pub use pair::partition_of;
pub use rdd::{Data, Rdd};
pub use shuffle::ShuffleManager;
pub use simclock::{SimCluster, SimJob, SimReport, SimStage, SimTask};
