//! Platform configuration: cluster shape, storage tiers, device models,
//! service knobs. Loaded from JSON (`adcloud --config cluster.json ...`)
//! or built from [`PlatformConfig::default`] / the preset constructors.

use anyhow::{Context, Result};
use std::path::Path;

use crate::util::json::Json;

/// Shape of the (real or simulated) cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Worker nodes. In real-execution mode each node is an executor
    /// thread group; in virtual-time mode they are simulated.
    pub nodes: usize,
    /// CPU cores per node (executor slots).
    pub cores_per_node: usize,
    /// GPU-class accelerators per node (PJRT device-server threads).
    pub gpus_per_node: usize,
    /// FPGA-class accelerators per node (modelled).
    pub fpgas_per_node: usize,
    /// Memory per node, bytes (drives tiered-store sizing).
    pub mem_per_node: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            nodes: 4,
            cores_per_node: 2,
            gpus_per_node: 1,
            fpgas_per_node: 1,
            mem_per_node: 512 << 20,
        }
    }
}

impl ClusterConfig {
    pub fn total_cores(&self) -> usize {
        self.nodes * self.cores_per_node
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("nodes", Json::num(self.nodes as f64)),
            ("cores_per_node", Json::num(self.cores_per_node as f64)),
            ("gpus_per_node", Json::num(self.gpus_per_node as f64)),
            ("fpgas_per_node", Json::num(self.fpgas_per_node as f64)),
            ("mem_per_node", Json::num(self.mem_per_node as f64)),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            nodes: j.req("nodes")?.as_usize()?,
            cores_per_node: j.req("cores_per_node")?.as_usize()?,
            gpus_per_node: j.req("gpus_per_node")?.as_usize()?,
            fpgas_per_node: j.req("fpgas_per_node")?.as_usize()?,
            mem_per_node: j.req("mem_per_node")?.as_u64()?,
        })
    }
}

/// One storage tier's capacity + device model.
#[derive(Debug, Clone, PartialEq)]
pub struct TierConfig {
    pub capacity_bytes: u64,
    /// Modelled sequential bandwidth, bytes/sec.
    pub bandwidth_bps: f64,
    /// Modelled fixed access latency per op, microseconds.
    pub latency_us: u64,
}

impl TierConfig {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("capacity_bytes", Json::num(self.capacity_bytes as f64)),
            ("bandwidth_bps", Json::num(self.bandwidth_bps)),
            ("latency_us", Json::num(self.latency_us as f64)),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            capacity_bytes: j.req("capacity_bytes")?.as_f64()? as u64,
            bandwidth_bps: j.req("bandwidth_bps")?.as_f64()?,
            latency_us: j.req("latency_us")?.as_u64()?,
        })
    }
}

/// Storage layout: the Alluxio-analog tier stack plus the HDFS-analog
/// baseline device. `model_devices=false` turns all modelled waits off
/// (unit tests); benches turn it on to reproduce the paper's I/O shapes.
#[derive(Debug, Clone, PartialEq)]
pub struct StorageConfig {
    pub mem: TierConfig,
    pub ssd: TierConfig,
    pub hdd: TierConfig,
    /// DFS (HDFS-analog) device: disk bandwidth + network round trip.
    pub dfs: TierConfig,
    pub model_devices: bool,
    /// Lock stripes for the tiered store's block map. Victim selection
    /// is still globally ordered (each shard keeps a per-tier eviction
    /// index and the evictor takes the min across shards), so the
    /// shard count changes contention, never eviction order.
    pub shards: usize,
    /// A/B baseline knob (`adcloud --baseline`, experiment E17): force
    /// the pre-PR-5 storage path — one shard, one global lock, and an
    /// O(n) full-map scan per eviction victim.
    pub scan_evict: bool,
}

/// Default lock-stripe count for the tiered store's block map.
pub const DEFAULT_STORE_SHARDS: usize = 16;

/// Default lock-stripe count for the shuffle manager's bucket map.
pub const DEFAULT_SHUFFLE_SHARDS: usize = 16;

impl Default for StorageConfig {
    fn default() -> Self {
        Self {
            // Capacities are deliberately small so eviction paths are
            // exercised; benches override them per experiment. Rates are
            // calibrated to the paper's 2017 datacenter classes:
            // MEM models the *Alluxio client effective path* (~3 GB/s,
            // serialisation included — not raw DRAM), SSD a SATA device,
            // HDD a 7.2k spindle, DFS a 1 GbE remote HDFS read.
            mem: TierConfig { capacity_bytes: 256 << 20, bandwidth_bps: 3e9, latency_us: 1 },
            ssd: TierConfig { capacity_bytes: 1 << 30, bandwidth_bps: 1.8e9, latency_us: 80 },
            hdd: TierConfig { capacity_bytes: 8 << 30, bandwidth_bps: 150e6, latency_us: 8_000 },
            dfs: TierConfig { capacity_bytes: u64::MAX, bandwidth_bps: 120e6, latency_us: 5_000 },
            model_devices: false,
            shards: DEFAULT_STORE_SHARDS,
            scan_evict: false,
        }
    }
}

impl StorageConfig {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mem", self.mem.to_json()),
            ("ssd", self.ssd.to_json()),
            ("hdd", self.hdd.to_json()),
            ("dfs", self.dfs.to_json()),
            ("model_devices", Json::Bool(self.model_devices)),
            ("shards", Json::num(self.shards as f64)),
            ("scan_evict", Json::Bool(self.scan_evict)),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            mem: TierConfig::from_json(j.req("mem")?)?,
            ssd: TierConfig::from_json(j.req("ssd")?)?,
            hdd: TierConfig::from_json(j.req("hdd")?)?,
            dfs: TierConfig::from_json(j.req("dfs")?)?,
            model_devices: j.req("model_devices")?.as_bool()?,
            // Optional for configs saved before the sharded store.
            shards: j
                .get("shards")
                .map(|s| s.as_usize())
                .transpose()?
                .unwrap_or(DEFAULT_STORE_SHARDS),
            scan_evict: j
                .get("scan_evict")
                .map(|s| s.as_bool())
                .transpose()?
                .unwrap_or(false),
        })
    }
}

/// Knobs for the compute engine.
#[derive(Debug, Clone, PartialEq)]
pub struct EngineConfig {
    /// Default number of partitions for parallelize/shuffle.
    pub default_parallelism: usize,
    /// Task retry limit before failing the job.
    pub max_task_retries: usize,
    /// Whether shuffle blocks flow through the tiered store (unified
    /// infrastructure) or the DFS baseline.
    pub shuffle_through_tiered: bool,
    /// Lock stripes for the shuffle manager's bucket map, routed by
    /// `(shuffle, reduce_part)` so a reduce partition's whole bucket
    /// row shares one shard.
    pub shuffle_shards: usize,
    /// A/B baseline knob (`adcloud --baseline`, experiment E22): force
    /// the pre-PR-10 shuffle path — one global bucket lock, per-bucket
    /// lock reacquisition in take, per-charge transport locking, and no
    /// combine/affinity/spill.
    pub shuffle_single_lock: bool,
    /// Resident-byte budget for shuffle buckets; buckets past it spill
    /// to the tiered store. 0 = unbounded (never spill).
    pub shuffle_spill_budget: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self {
            default_parallelism: 8,
            max_task_retries: 2,
            shuffle_through_tiered: true,
            shuffle_shards: DEFAULT_SHUFFLE_SHARDS,
            shuffle_single_lock: false,
            shuffle_spill_budget: 0,
        }
    }
}

impl EngineConfig {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("default_parallelism", Json::num(self.default_parallelism as f64)),
            ("max_task_retries", Json::num(self.max_task_retries as f64)),
            ("shuffle_through_tiered", Json::Bool(self.shuffle_through_tiered)),
            ("shuffle_shards", Json::num(self.shuffle_shards as f64)),
            ("shuffle_single_lock", Json::Bool(self.shuffle_single_lock)),
            ("shuffle_spill_budget", Json::num(self.shuffle_spill_budget as f64)),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            default_parallelism: j.req("default_parallelism")?.as_usize()?,
            max_task_retries: j.req("max_task_retries")?.as_usize()?,
            shuffle_through_tiered: j.req("shuffle_through_tiered")?.as_bool()?,
            // Optional for configs saved before the sharded shuffle.
            shuffle_shards: j
                .get("shuffle_shards")
                .map(|s| s.as_usize())
                .transpose()?
                .unwrap_or(DEFAULT_SHUFFLE_SHARDS),
            shuffle_single_lock: j
                .get("shuffle_single_lock")
                .map(|s| s.as_bool())
                .transpose()?
                .unwrap_or(false),
            shuffle_spill_budget: j
                .get("shuffle_spill_budget")
                .map(|s| s.as_u64())
                .transpose()?
                .unwrap_or(0),
        })
    }
}

/// Top-level platform configuration.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PlatformConfig {
    pub cluster: ClusterConfig,
    pub storage: StorageConfig,
    pub engine: EngineConfig,
    /// Seed for every synthetic workload generator.
    pub seed: u64,
}

impl PlatformConfig {
    /// Small config used by unit/integration tests: no device models,
    /// tiny tiers, 2 nodes.
    pub fn test() -> Self {
        Self {
            cluster: ClusterConfig {
                nodes: 2,
                cores_per_node: 2,
                gpus_per_node: 1,
                fpgas_per_node: 1,
                mem_per_node: 64 << 20,
            },
            storage: StorageConfig {
                mem: TierConfig { capacity_bytes: 4 << 20, bandwidth_bps: 12e9, latency_us: 0 },
                ssd: TierConfig { capacity_bytes: 16 << 20, bandwidth_bps: 2e9, latency_us: 0 },
                hdd: TierConfig { capacity_bytes: 64 << 20, bandwidth_bps: 200e6, latency_us: 0 },
                dfs: TierConfig { capacity_bytes: u64::MAX, bandwidth_bps: 120e6, latency_us: 0 },
                model_devices: false,
                shards: DEFAULT_STORE_SHARDS,
                scan_evict: false,
            },
            engine: EngineConfig {
                default_parallelism: 4,
                max_task_retries: 2,
                shuffle_through_tiered: true,
                shuffle_shards: DEFAULT_SHUFFLE_SHARDS,
                shuffle_single_lock: false,
                shuffle_spill_budget: 0,
            },
            seed: 42,
        }
    }

    /// Bench preset: device models ON so storage/network costs reproduce
    /// the paper's I/O-bound shapes.
    pub fn bench() -> Self {
        let mut c = Self::default();
        c.storage.model_devices = true;
        c
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cluster", self.cluster.to_json()),
            ("storage", self.storage.to_json()),
            ("engine", self.engine.to_json()),
            ("seed", Json::num(self.seed as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            cluster: ClusterConfig::from_json(j.req("cluster")?)?,
            storage: StorageConfig::from_json(j.req("storage")?)?,
            engine: EngineConfig::from_json(j.req("engine")?)?,
            seed: j.get("seed").map(|s| s.as_u64()).transpose()?.unwrap_or(0),
        })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Self::from_json(&Json::parse(&text).context("parsing config JSON")?)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrips_json() {
        let c = PlatformConfig::default();
        let d = PlatformConfig::from_json(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap();
        // u64::MAX survives only approximately through f64; compare the
        // fields that must be exact.
        assert_eq!(d.cluster, c.cluster);
        assert_eq!(d.engine, c.engine);
        assert_eq!(d.storage.mem, c.storage.mem);
    }

    #[test]
    fn save_and_load() {
        let dir = std::env::temp_dir().join("adcloud_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.json");
        let c = PlatformConfig::test();
        c.save(&p).unwrap();
        let d = PlatformConfig::load(&p).unwrap();
        assert_eq!(d.cluster.nodes, 2);
        assert_eq!(d.seed, 42);
    }

    #[test]
    fn total_cores() {
        let c = ClusterConfig { nodes: 3, cores_per_node: 4, ..Default::default() };
        assert_eq!(c.total_cores(), 12);
    }

    #[test]
    fn missing_key_is_error() {
        assert!(PlatformConfig::from_json(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn pre_sharding_configs_still_load() {
        // A config saved before the sharded store / sharded shuffle has
        // none of the later knobs; it must parse with the defaults.
        let mut j = PlatformConfig::default().to_json().to_string();
        j = j.replace("\"shards\":16,", "").replace("\"scan_evict\":false,", "");
        j = j
            .replace("\"shuffle_shards\":16,", "")
            .replace("\"shuffle_single_lock\":false,", "")
            .replace("\"shuffle_spill_budget\":0,", "");
        let c = PlatformConfig::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(c.storage.shards, DEFAULT_STORE_SHARDS);
        assert!(!c.storage.scan_evict);
        assert_eq!(c.engine.shuffle_shards, DEFAULT_SHUFFLE_SHARDS);
        assert!(!c.engine.shuffle_single_lock);
        assert_eq!(c.engine.shuffle_spill_budget, 0);
    }
}
