//! Lightweight metrics: counters, gauges, histograms and scoped timers.
//!
//! Every subsystem (storage tiers, shuffle, executors, device dispatch)
//! reports through a shared [`MetricsRegistry`]; benches and the CLI
//! render [`MetricsRegistry::report`] tables, which is how the paper-style
//! experiment rows in EXPERIMENTS.md are produced.
//!
//! **Hot paths use pre-resolved handles.** `registry.counter(name)`
//! takes the registry lock and allocates the name on every call, which
//! is fine for `report()` but not for a per-put/per-append/per-shard
//! loop. The handle structs below ([`StoreMetrics`], [`LogMetrics`],
//! [`GatewayMetrics`], [`JobMetrics`], [`CampaignMetrics`]) resolve
//! their `Arc<Counter>`/`Arc<Histogram>`s once at construction; the
//! name-keyed API stays the source of truth, so `report()` and
//! name-based test assertions see exactly the same atomics.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A point-in-time level that can move both ways (live containers,
/// bytes resident per storage tier). `set` overwrites; `add`/`sub`
/// adjust, saturating at zero rather than wrapping.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn sub(&self, n: u64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_sub(n);
            match self.0.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Fixed-boundary latency histogram (microseconds), lock-free on record.
#[derive(Debug)]
pub struct Histogram {
    /// Bucket upper bounds in microseconds.
    bounds: Vec<u64>,
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        // 1us .. ~1000s, roughly x4 per bucket.
        let bounds: Vec<u64> = (0..16).map(|i| 1u64 << (2 * i)).collect();
        let buckets = (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect();
        Self {
            bounds,
            buckets,
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = self
            .bounds
            .iter()
            .position(|&b| us <= b)
            .unwrap_or(self.bounds.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / c)
    }

    pub fn max(&self) -> Duration {
        Duration::from_micros(self.max_us.load(Ordering::Relaxed))
    }

    pub fn total(&self) -> Duration {
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed))
    }

    /// Quantile with linear interpolation inside the winning bucket.
    /// The bucket's lower bound is the floor — the overflow bucket
    /// interpolates between the last bound and the observed max, so a
    /// p99 can no longer be overstated by a whole x4 bucket width.
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = (q * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n > 0 && seen + n >= target {
                let lo = if i == 0 { 0 } else { self.bounds[i - 1] };
                let hi = if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    self.max_us.load(Ordering::Relaxed).max(lo)
                };
                let frac = (target - seen) as f64 / n as f64;
                let us = lo as f64 + frac * (hi - lo) as f64;
                return Duration::from_micros(us.round() as u64);
            }
            seen += n;
        }
        self.max()
    }
}

/// Shared registry of named metrics.
#[derive(Debug, Default, Clone)]
pub struct MetricsRegistry {
    inner: Arc<MetricsInner>,
}

#[derive(Debug, Default)]
struct MetricsInner {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.inner
            .counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.inner
            .gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.inner
            .histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Time a closure into the named histogram.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let h = self.histogram(name);
        let start = Instant::now();
        let out = f();
        h.record(start.elapsed());
        out
    }

    /// Render all metrics as an aligned text table.
    pub fn report(&self) -> String {
        let mut out = String::new();
        let counters = self.inner.counters.lock().unwrap();
        if !counters.is_empty() {
            out.push_str("counters:\n");
            for (k, v) in counters.iter() {
                out.push_str(&format!("  {:<44} {}\n", k, v.get()));
            }
        }
        let gauges = self.inner.gauges.lock().unwrap();
        if !gauges.is_empty() {
            out.push_str("gauges:\n");
            for (k, g) in gauges.iter() {
                out.push_str(&format!("  {:<44} {}\n", k, g.get()));
            }
        }
        let hists = self.inner.histograms.lock().unwrap();
        if !hists.is_empty() {
            out.push_str("timings:\n");
            for (k, h) in hists.iter() {
                if h.count() == 0 {
                    continue;
                }
                out.push_str(&format!(
                    "  {:<44} n={:<8} mean={:<10} p99={:<10} max={}\n",
                    k,
                    h.count(),
                    crate::util::fmt_duration(h.mean()),
                    crate::util::fmt_duration(h.quantile(0.99)),
                    crate::util::fmt_duration(h.max()),
                ));
            }
        }
        out
    }

    /// Machine-readable snapshot of every metric: counters and gauges
    /// as numbers, histograms as `{count, mean_us, p50_us, p99_us,
    /// max_us, total_us}`. Embedded wholesale in `BENCH_*.json` rows
    /// so experiment artifacts carry the full picture, not a
    /// hand-picked column subset.
    pub fn report_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let counters: Vec<(String, Json)> = self
            .inner
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), Json::num(v.get() as f64)))
            .collect();
        let gauges: Vec<(String, Json)> = self
            .inner
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, g)| (k.clone(), Json::num(g.get() as f64)))
            .collect();
        let hists: Vec<(String, Json)> = self
            .inner
            .histograms
            .lock()
            .unwrap()
            .iter()
            .filter(|(_, h)| h.count() > 0)
            .map(|(k, h)| {
                let us = |d: Duration| Json::num(d.as_micros() as f64);
                let v = Json::obj(vec![
                    ("count", Json::num(h.count() as f64)),
                    ("mean_us", us(h.mean())),
                    ("p50_us", us(h.quantile(0.5))),
                    ("p99_us", us(h.quantile(0.99))),
                    ("max_us", us(h.max())),
                    ("total_us", us(h.total())),
                ]);
                (k.clone(), v)
            })
            .collect();
        let obj = |pairs: Vec<(String, Json)>| Json::Obj(pairs.into_iter().collect());
        Json::obj(vec![
            ("counters", obj(counters)),
            ("gauges", obj(gauges)),
            ("histograms", obj(hists)),
        ])
    }

    /// Reset everything (used between bench iterations).
    pub fn clear(&self) {
        self.inner.counters.lock().unwrap().clear();
        self.inner.gauges.lock().unwrap().clear();
        self.inner.histograms.lock().unwrap().clear();
    }

    /// Clone out `(name, handle)` pairs for every registered metric.
    /// The sampler (`crate::obs`) calls this once per tick and then
    /// reads the shared atomics directly — registry locks are only
    /// taken here, on the sampler thread, never on a recording path.
    pub fn handles(&self) -> MetricHandles {
        MetricHandles {
            counters: self
                .inner
                .counters
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect(),
            gauges: self
                .inner
                .gauges
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect(),
            histograms: self
                .inner
                .histograms
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect(),
        }
    }
}

/// A point-in-time clone of the registry's name→handle maps (see
/// [`MetricsRegistry::handles`]). The `Arc`s alias the live atomics, so
/// holding one of these reads current values without re-locking.
#[derive(Default)]
pub struct MetricHandles {
    pub counters: Vec<(String, Arc<Counter>)>,
    pub gauges: Vec<(String, Arc<Gauge>)>,
    pub histograms: Vec<(String, Arc<Histogram>)>,
}

/// RAII timer recording into a histogram on drop.
pub struct ScopedTimer {
    hist: Arc<Histogram>,
    start: Instant,
}

impl ScopedTimer {
    pub fn new(hist: Arc<Histogram>) -> Self {
        Self { hist, start: Instant::now() }
    }
}

impl Drop for ScopedTimer {
    fn drop(&mut self) {
        self.hist.record(self.start.elapsed());
    }
}

/// Pre-resolved handles for the tiered store's per-op counters
/// (`storage.tiered.*` + the checkpoint counters that ride the store).
/// Indexed arrays replace the old per-get
/// `format!("storage.tiered.hit.{tier}")` allocation.
#[derive(Clone)]
pub struct StoreMetrics {
    pub puts: Arc<Counter>,
    /// Tier hits, indexed mem/ssd/hdd.
    pub hits: [Arc<Counter>; 3],
    /// Tier evictions, indexed mem/ssd/hdd.
    pub evicts: [Arc<Counter>; 3],
    pub miss: Arc<Counter>,
    pub writeback: Arc<Counter>,
    pub lineage_recovered: Arc<Counter>,
    /// Bytes resident per tier, indexed mem/ssd/hdd
    /// (`storage.tier_used.*`), refreshed on put/evict/delete.
    pub tier_used: [Arc<Gauge>; 3],
    pub ckpt_commits: Arc<Counter>,
    pub ckpt_hits: Arc<Counter>,
    pub ckpt_swept: Arc<Counter>,
    /// Blobs deleted because their put-time TTL expired
    /// (`expire_ttl`, the scan-free ckpt GC path).
    pub ttl_expired: Arc<Counter>,
}

impl StoreMetrics {
    pub fn new(reg: &MetricsRegistry) -> Self {
        let tiered = |t: &str| reg.counter(&format!("storage.tiered.{t}"));
        Self {
            puts: tiered("puts"),
            hits: [tiered("hit.mem"), tiered("hit.ssd"), tiered("hit.hdd")],
            evicts: [tiered("evict.mem"), tiered("evict.ssd"), tiered("evict.hdd")],
            miss: tiered("miss"),
            writeback: tiered("writeback"),
            lineage_recovered: tiered("lineage_recovered"),
            tier_used: [
                reg.gauge("storage.tier_used.mem"),
                reg.gauge("storage.tier_used.ssd"),
                reg.gauge("storage.tier_used.hdd"),
            ],
            ckpt_commits: reg.counter("platform.ckpt.commits"),
            ckpt_hits: reg.counter("platform.ckpt.hits"),
            ckpt_swept: reg.counter("platform.ckpt.swept"),
            ttl_expired: reg.counter("storage.tiered.ttl_expired"),
        }
    }
}

/// Pre-resolved handles for the partitioned log's append path
/// (`ingest.log.*`).
#[derive(Clone)]
pub struct LogMetrics {
    pub appends: Arc<Counter>,
    pub bytes: Arc<Counter>,
    pub truncated_segments: Arc<Counter>,
    pub lost_unconsumed: Arc<Counter>,
    /// Group-commit batches landed via `append_batch`.
    pub batch_appends: Arc<Counter>,
    /// Bytes dropped by crash recovery truncating torn batch tails.
    pub torn_tail_bytes: Arc<Counter>,
}

impl LogMetrics {
    pub fn new(reg: &MetricsRegistry) -> Self {
        Self {
            appends: reg.counter("ingest.log.appends"),
            bytes: reg.counter("ingest.log.bytes"),
            truncated_segments: reg.counter("ingest.log.truncated_segments"),
            lost_unconsumed: reg.counter("ingest.log.lost_unconsumed"),
            batch_appends: reg.counter("ingest.log.batch_appends"),
            torn_tail_bytes: reg.counter("ingest.log.torn_tail_bytes"),
        }
    }
}

/// Pre-resolved handles for the ingest gateway's admission path
/// (`ingest.gateway.*`, one decision per upload).
#[derive(Clone)]
pub struct GatewayMetrics {
    pub accepted: Arc<Counter>,
    pub throttled: Arc<Counter>,
    pub dead_lettered: Arc<Counter>,
    pub backpressured: Arc<Counter>,
    /// Dead letters currently parked at the gateway (watchdog input).
    pub dlq_depth: Arc<Gauge>,
    /// Worst produced-minus-committed lag across partitions, updated
    /// on every admission decision (watchdog input).
    pub partition_lag: Arc<Gauge>,
    /// Batched admission rounds handled via `upload_batch`.
    pub batches: Arc<Counter>,
}

impl GatewayMetrics {
    pub fn new(reg: &MetricsRegistry) -> Self {
        Self {
            accepted: reg.counter("ingest.gateway.accepted"),
            throttled: reg.counter("ingest.gateway.throttled"),
            dead_lettered: reg.counter("ingest.gateway.dead_lettered"),
            backpressured: reg.counter("ingest.gateway.backpressured"),
            dlq_depth: reg.gauge("ingest.gateway.dlq_depth"),
            partition_lag: reg.gauge("ingest.gateway.partition_lag"),
            batches: reg.counter("ingest.gateway.batches"),
        }
    }
}

/// Pre-resolved handles for the unified job layer (`platform.job.*`,
/// touched per shard attempt and per preemption requeue).
#[derive(Clone)]
pub struct JobMetrics {
    pub jobs: Arc<Counter>,
    pub grant_wait: Arc<Histogram>,
    pub shard_retries: Arc<Counter>,
    pub shard_panics: Arc<Counter>,
    pub preemptions: Arc<Counter>,
    pub preempt_requeue_wait: Arc<Histogram>,
    pub container_ms: Arc<Counter>,
}

impl JobMetrics {
    pub fn new(reg: &MetricsRegistry) -> Self {
        Self {
            jobs: reg.counter("platform.job.jobs"),
            grant_wait: reg.histogram("platform.job.grant_wait"),
            shard_retries: reg.counter("platform.job.shard_retries"),
            shard_panics: reg.counter("platform.job.shard_panics"),
            preemptions: reg.counter("platform.job.preemptions"),
            preempt_requeue_wait: reg.histogram("platform.job.preempt_requeue_wait"),
            container_ms: reg.counter("platform.job.container_ms"),
        }
    }
}

/// Pre-resolved handles for the campaign scoring loop (`scenario.*`,
/// touched once per scenario inside every shard).
#[derive(Clone)]
pub struct CampaignMetrics {
    pub campaigns: Arc<Counter>,
    pub scored: Arc<Counter>,
    pub ckpt_hits: Arc<Counter>,
    pub ckpt_corrupt: Arc<Counter>,
    pub scenarios_run: Arc<Counter>,
}

impl CampaignMetrics {
    pub fn new(reg: &MetricsRegistry) -> Self {
        Self {
            campaigns: reg.counter("scenario.campaigns"),
            scored: reg.counter("scenario.scored"),
            ckpt_hits: reg.counter("scenario.ckpt_hits"),
            ckpt_corrupt: reg.counter("scenario.ckpt_corrupt"),
            scenarios_run: reg.counter("scenario.scenarios_run"),
        }
    }
}

/// Pre-resolved handles for the online serving plane (`serve.*`,
/// touched once per offload request on the admission/dispatch path).
#[derive(Clone)]
pub struct ServeMetrics {
    pub requests: Arc<Counter>,
    pub admitted: Arc<Counter>,
    /// Rejected on arrival: queue-delay estimate already exceeded the
    /// request's deadline slack, so running it would waste a slot.
    pub rejected: Arc<Counter>,
    pub completed: Arc<Counter>,
    /// Admitted requests whose response landed after the deadline.
    pub deadline_misses: Arc<Counter>,
    /// Speculative local-model completions: degraded quality, not a miss.
    pub fallbacks: Arc<Counter>,
    /// Admitted requests currently waiting for a worker (EDF queue depth).
    pub queue_depth: Arc<Gauge>,
    /// End-to-end request latency; the sampler exports
    /// `serve.latency.p50/.p99/.p999` from this histogram.
    pub latency: Arc<Histogram>,
}

impl ServeMetrics {
    pub fn new(reg: &MetricsRegistry) -> Self {
        Self {
            requests: reg.counter("serve.requests"),
            admitted: reg.counter("serve.admitted"),
            rejected: reg.counter("serve.rejected"),
            completed: reg.counter("serve.completed"),
            deadline_misses: reg.counter("serve.deadline_misses"),
            fallbacks: reg.counter("serve.fallbacks"),
            queue_depth: reg.gauge("serve.queue_depth"),
            latency: reg.histogram("serve.latency"),
        }
    }
}

/// Pre-resolved handles for the sharded shuffle plane
/// (`dce.shuffle.*`, touched once per bucket put/take).
#[derive(Clone)]
pub struct ShuffleMetrics {
    pub bytes_written: Arc<Counter>,
    pub buckets_written: Arc<Counter>,
    pub bytes_read: Arc<Counter>,
    /// Records entering map-side combine.
    pub combine_in: Arc<Counter>,
    /// Records shipped after combining.
    pub combine_out: Arc<Counter>,
    /// Cumulative input records per 100 shipped (100 = no combining,
    /// 300 = 3:1 reduction).
    pub combine_ratio: Arc<Gauge>,
    pub spilled_buckets: Arc<Counter>,
    pub spilled_bytes: Arc<Counter>,
    /// Spilled buckets successfully read back at take time.
    pub spill_restored: Arc<Counter>,
    /// Spilled blobs gone at take time (surfaces as a fetch failure).
    pub spill_lost: Arc<Counter>,
    /// Bucket bytes currently resident in memory (spilled excluded).
    pub resident_bytes: Arc<Gauge>,
    /// Hinted reduce tasks that ran on their preferred worker.
    pub affinity_hits: Arc<Counter>,
    pub affinity_misses: Arc<Counter>,
}

impl ShuffleMetrics {
    pub fn new(reg: &MetricsRegistry) -> Self {
        let c = |t: &str| reg.counter(&format!("dce.shuffle.{t}"));
        Self {
            bytes_written: c("bytes_written"),
            buckets_written: c("buckets_written"),
            bytes_read: c("bytes_read"),
            combine_in: c("combine_in"),
            combine_out: c("combine_out"),
            combine_ratio: reg.gauge("dce.shuffle.combine_ratio"),
            spilled_buckets: c("spilled_buckets"),
            spilled_bytes: c("spilled_bytes"),
            spill_restored: c("spill_restored"),
            spill_lost: c("spill_lost"),
            resident_bytes: reg.gauge("dce.shuffle.resident_bytes"),
            affinity_hits: c("affinity_hits"),
            affinity_misses: c("affinity_misses"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_accumulates() {
        let m = MetricsRegistry::new();
        m.counter("x").inc();
        m.counter("x").add(4);
        assert_eq!(m.counter("x").get(), 5);
    }

    #[test]
    fn histogram_stats() {
        let h = Histogram::default();
        for ms in [1u64, 2, 3, 4, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert!(h.mean() >= Duration::from_millis(10));
        assert!(h.max() >= Duration::from_millis(100));
        assert!(h.quantile(0.5) <= h.quantile(0.99));
    }

    #[test]
    fn quantile_interpolates_within_the_winning_bucket() {
        // 100 samples of 10us all land in the (4, 16] bucket. The old
        // code returned the bucket's upper bound (16us) for every
        // quantile; interpolation pins the exact positions.
        let h = Histogram::default();
        for _ in 0..100 {
            h.record(Duration::from_micros(10));
        }
        // target = 50 of 100 -> halfway through [4, 16] = 10us.
        assert_eq!(h.quantile(0.5), Duration::from_micros(10));
        // target = 25 -> 4 + 0.25 * 12 = 7us.
        assert_eq!(h.quantile(0.25), Duration::from_micros(7));
        // target = 100 -> the bucket's upper bound.
        assert_eq!(h.quantile(1.0), Duration::from_micros(16));
    }

    #[test]
    fn quantile_skips_empty_buckets_and_spans_distributions() {
        // 50 samples at 3us (bucket (1,4]) and 50 at 40us (bucket
        // (16,64]): the median sits at the top of the low bucket, p75
        // exactly halfway through the high one.
        let h = Histogram::default();
        for _ in 0..50 {
            h.record(Duration::from_micros(3));
        }
        for _ in 0..50 {
            h.record(Duration::from_micros(40));
        }
        assert_eq!(h.quantile(0.5), Duration::from_micros(4));
        // target = 75, 25 into the 50-sample bucket: 16 + 24 = 40us.
        assert_eq!(h.quantile(0.75), Duration::from_micros(40));
    }

    #[test]
    fn quantile_overflow_bucket_floors_at_the_last_bound() {
        // Two samples past the last bound (1 << 30 us): interpolate
        // between that bound and the observed max, not jump to max.
        let top = 1u64 << 30;
        let h = Histogram::default();
        h.record(Duration::from_micros(2 * top));
        h.record(Duration::from_micros(2 * top));
        // target = 1 of 2 -> halfway between top and 2*top.
        assert_eq!(h.quantile(0.5), Duration::from_micros(top + top / 2));
        assert_eq!(h.quantile(1.0), Duration::from_micros(2 * top));
    }

    #[test]
    fn gauge_moves_both_ways_and_saturates() {
        let m = MetricsRegistry::new();
        let g = m.gauge("resource.live_containers");
        g.add(5);
        g.sub(2);
        assert_eq!(m.gauge("resource.live_containers").get(), 3);
        g.sub(100);
        assert_eq!(g.get(), 0, "sub saturates instead of wrapping");
        g.set(7);
        assert_eq!(g.get(), 7);
        assert!(m.report().contains("gauges:"));
        assert!(m.report().contains("resource.live_containers"));
    }

    #[test]
    fn report_json_snapshots_every_metric_kind() {
        let m = MetricsRegistry::new();
        m.counter("a.count").add(3);
        m.gauge("b.level").set(9);
        m.histogram("c.lat").record(Duration::from_micros(10));
        let j = m.report_json();
        let counters = j.req("counters").unwrap();
        assert_eq!(counters.req("a.count").unwrap().as_u64().unwrap(), 3);
        let gauges = j.req("gauges").unwrap();
        assert_eq!(gauges.req("b.level").unwrap().as_u64().unwrap(), 9);
        let hist = j.req("histograms").unwrap().req("c.lat").unwrap();
        assert_eq!(hist.req("count").unwrap().as_u64().unwrap(), 1);
        assert_eq!(hist.req("max_us").unwrap().as_u64().unwrap(), 10);
        // Round-trips through the in-tree codec.
        let text = j.to_string_pretty();
        let back = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn time_records() {
        let m = MetricsRegistry::new();
        let v = m.time("op", || 42);
        assert_eq!(v, 42);
        assert_eq!(m.histogram("op").count(), 1);
    }

    #[test]
    fn report_renders() {
        let m = MetricsRegistry::new();
        m.counter("a.b").add(3);
        m.time("c.d", || ());
        let r = m.report();
        assert!(r.contains("a.b"));
        assert!(r.contains("c.d"));
    }

    #[test]
    fn handle_structs_alias_the_registry_atomics() {
        // A handle resolved before OR after name-keyed traffic must see
        // the same counter — report() and handles never diverge.
        let m = MetricsRegistry::new();
        let h = StoreMetrics::new(&m);
        h.puts.inc();
        m.counter("storage.tiered.puts").inc();
        assert_eq!(m.counter("storage.tiered.puts").get(), 2);
        assert_eq!(h.puts.get(), 2);
        let j = JobMetrics::new(&m);
        j.grant_wait.record(Duration::from_millis(3));
        assert_eq!(m.histogram("platform.job.grant_wait").count(), 1);
    }

    #[test]
    fn scoped_timer_records_on_drop() {
        let m = MetricsRegistry::new();
        {
            let _t = ScopedTimer::new(m.histogram("scope"));
        }
        assert_eq!(m.histogram("scope").count(), 1);
    }
}
