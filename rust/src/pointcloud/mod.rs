//! Point-cloud math substrate for HD map generation: SE(3) poses, a
//! KD-tree for nearest-neighbour queries, and the small symmetric-3x3
//! eigensolver / SVD used to close each ICP iteration (the artifact
//! returns the cross-covariance; the 3x3 Kabsch solve happens here
//! because the old XLA CPU runtime lacks LAPACK custom-calls).

pub mod kdtree;
pub mod solve;

pub use kdtree::KdTree;
pub use solve::{kabsch_rotation, svd3};

/// 3-vector helpers over `[f32; 3]`.
pub type Vec3 = [f32; 3];

pub fn v_add(a: Vec3, b: Vec3) -> Vec3 {
    [a[0] + b[0], a[1] + b[1], a[2] + b[2]]
}

pub fn v_sub(a: Vec3, b: Vec3) -> Vec3 {
    [a[0] - b[0], a[1] - b[1], a[2] - b[2]]
}

pub fn v_dot(a: Vec3, b: Vec3) -> f32 {
    a[0] * b[0] + a[1] * b[1] + a[2] * b[2]
}

pub fn v_cross(a: Vec3, b: Vec3) -> Vec3 {
    [
        a[1] * b[2] - a[2] * b[1],
        a[2] * b[0] - a[0] * b[2],
        a[0] * b[1] - a[1] * b[0],
    ]
}

pub fn v_norm(a: Vec3) -> f32 {
    v_dot(a, a).sqrt()
}

pub fn v_scale(a: Vec3, s: f32) -> Vec3 {
    [a[0] * s, a[1] * s, a[2] * s]
}

/// Row-major 3x3 matrix.
pub type Mat3 = [[f32; 3]; 3];

pub const MAT3_ID: Mat3 = [[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]];

pub fn m_mul(a: &Mat3, b: &Mat3) -> Mat3 {
    let mut o = [[0f32; 3]; 3];
    for i in 0..3 {
        for j in 0..3 {
            for k in 0..3 {
                o[i][j] += a[i][k] * b[k][j];
            }
        }
    }
    o
}

pub fn m_transpose(a: &Mat3) -> Mat3 {
    let mut o = [[0f32; 3]; 3];
    for i in 0..3 {
        for j in 0..3 {
            o[i][j] = a[j][i];
        }
    }
    o
}

pub fn m_apply(a: &Mat3, v: Vec3) -> Vec3 {
    [
        a[0][0] * v[0] + a[0][1] * v[1] + a[0][2] * v[2],
        a[1][0] * v[0] + a[1][1] * v[1] + a[1][2] * v[2],
        a[2][0] * v[0] + a[2][1] * v[1] + a[2][2] * v[2],
    ]
}

pub fn m_det(a: &Mat3) -> f32 {
    a[0][0] * (a[1][1] * a[2][2] - a[1][2] * a[2][1])
        - a[0][1] * (a[1][0] * a[2][2] - a[1][2] * a[2][0])
        + a[0][2] * (a[1][0] * a[2][1] - a[1][1] * a[2][0])
}

/// Rotation about Z (the dominant motion of a ground vehicle).
pub fn rot_z(theta: f32) -> Mat3 {
    let (s, c) = theta.sin_cos();
    [[c, -s, 0.0], [s, c, 0.0], [0.0, 0.0, 1.0]]
}

/// A rigid transform (pose): x ↦ R x + t.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Se3 {
    pub r: Mat3,
    pub t: Vec3,
}

impl Default for Se3 {
    fn default() -> Self {
        Self::identity()
    }
}

impl Se3 {
    pub fn identity() -> Self {
        Self { r: MAT3_ID, t: [0.0; 3] }
    }

    pub fn new(r: Mat3, t: Vec3) -> Self {
        Self { r, t }
    }

    pub fn apply(&self, p: Vec3) -> Vec3 {
        v_add(m_apply(&self.r, p), self.t)
    }

    /// Composition: (self ∘ other)(x) = self(other(x)).
    pub fn compose(&self, other: &Se3) -> Se3 {
        Se3 { r: m_mul(&self.r, &other.r), t: v_add(m_apply(&self.r, other.t), self.t) }
    }

    pub fn inverse(&self) -> Se3 {
        let rt = m_transpose(&self.r);
        Se3 { r: rt, t: v_scale(m_apply(&rt, self.t), -1.0) }
    }

    /// Apply to a packed (N,3) cloud.
    pub fn apply_cloud(&self, pts: &[f32]) -> Vec<f32> {
        let mut out = Vec::with_capacity(pts.len());
        for p in pts.chunks_exact(3) {
            let q = self.apply([p[0], p[1], p[2]]);
            out.extend_from_slice(&q);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vector_algebra() {
        let a = [1.0, 2.0, 3.0];
        let b = [4.0, 5.0, 6.0];
        assert_eq!(v_add(a, b), [5.0, 7.0, 9.0]);
        assert_eq!(v_dot(a, b), 32.0);
        assert_eq!(v_cross([1.0, 0.0, 0.0], [0.0, 1.0, 0.0]), [0.0, 0.0, 1.0]);
        assert!((v_norm([3.0, 4.0, 0.0]) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn rotation_is_orthonormal() {
        let r = rot_z(0.7);
        let rtr = m_mul(&m_transpose(&r), &r);
        for i in 0..3 {
            for j in 0..3 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((rtr[i][j] - want).abs() < 1e-6);
            }
        }
        assert!((m_det(&r) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn se3_compose_inverse() {
        let a = Se3::new(rot_z(0.3), [1.0, -2.0, 0.5]);
        let b = Se3::new(rot_z(-0.8), [0.0, 3.0, 1.0]);
        let p = [0.4, 0.2, -1.0];
        let via_compose = a.compose(&b).apply(p);
        let sequential = a.apply(b.apply(p));
        for k in 0..3 {
            assert!((via_compose[k] - sequential[k]).abs() < 1e-5);
        }
        let round = a.inverse().apply(a.apply(p));
        for k in 0..3 {
            assert!((round[k] - p[k]).abs() < 1e-5);
        }
    }

    #[test]
    fn apply_cloud_matches_pointwise() {
        let t = Se3::new(rot_z(1.0), [5.0, 0.0, 0.0]);
        let pts = vec![1.0f32, 0.0, 0.0, 0.0, 1.0, 0.0];
        let out = t.apply_cloud(&pts);
        let p0 = t.apply([1.0, 0.0, 0.0]);
        assert!((out[0] - p0[0]).abs() < 1e-6);
        assert!((out[1] - p0[1]).abs() < 1e-6);
        assert_eq!(out.len(), 6);
    }
}
