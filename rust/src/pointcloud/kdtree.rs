//! KD-tree over 3-D points: the CPU-side nearest-neighbour structure
//! for ICP refinement and map queries (an O(log n) alternative the
//! mapgen service uses where the brute-force kernel would be wasteful,
//! e.g. querying a large accumulated map cloud).

use super::Vec3;

#[derive(Debug, Clone)]
struct Node {
    point: Vec3,
    index: usize,
    axis: usize,
    left: Option<Box<Node>>,
    right: Option<Box<Node>>,
}

/// Static KD-tree built once over a cloud.
#[derive(Debug, Clone, Default)]
pub struct KdTree {
    root: Option<Box<Node>>,
    len: usize,
}

impl KdTree {
    /// Build from a packed (N,3) cloud.
    pub fn build(points: &[f32]) -> Self {
        let mut items: Vec<(Vec3, usize)> = points
            .chunks_exact(3)
            .enumerate()
            .map(|(i, p)| ([p[0], p[1], p[2]], i))
            .collect();
        let len = items.len();
        let root = Self::build_rec(&mut items, 0);
        Self { root, len }
    }

    fn build_rec(items: &mut [(Vec3, usize)], depth: usize) -> Option<Box<Node>> {
        if items.is_empty() {
            return None;
        }
        let axis = depth % 3;
        items.sort_by(|a, b| a.0[axis].partial_cmp(&b.0[axis]).unwrap());
        let mid = items.len() / 2;
        let (point, index) = items[mid];
        let (left_items, rest) = items.split_at_mut(mid);
        let right_items = &mut rest[1..];
        Some(Box::new(Node {
            point,
            index,
            axis,
            left: Self::build_rec(left_items, depth + 1),
            right: Self::build_rec(right_items, depth + 1),
        }))
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Nearest neighbour: (index, squared distance).
    pub fn nearest(&self, q: Vec3) -> Option<(usize, f32)> {
        let mut best: Option<(usize, f32)> = None;
        Self::nearest_rec(&self.root, q, &mut best);
        best
    }

    fn nearest_rec(node: &Option<Box<Node>>, q: Vec3, best: &mut Option<(usize, f32)>) {
        let Some(n) = node else { return };
        let d2 = {
            let dx = q[0] - n.point[0];
            let dy = q[1] - n.point[1];
            let dz = q[2] - n.point[2];
            dx * dx + dy * dy + dz * dz
        };
        if best.map(|(_, b)| d2 < b).unwrap_or(true) {
            *best = Some((n.index, d2));
        }
        let delta = q[n.axis] - n.point[n.axis];
        let (near, far) = if delta < 0.0 { (&n.left, &n.right) } else { (&n.right, &n.left) };
        Self::nearest_rec(near, q, best);
        if best.map(|(_, b)| delta * delta < b).unwrap_or(true) {
            Self::nearest_rec(far, q, best);
        }
    }

    /// All indices within `radius` of `q`.
    pub fn within_radius(&self, q: Vec3, radius: f32) -> Vec<usize> {
        let mut out = Vec::new();
        Self::radius_rec(&self.root, q, radius * radius, &mut out);
        out
    }

    fn radius_rec(node: &Option<Box<Node>>, q: Vec3, r2: f32, out: &mut Vec<usize>) {
        let Some(n) = node else { return };
        let dx = q[0] - n.point[0];
        let dy = q[1] - n.point[1];
        let dz = q[2] - n.point[2];
        if dx * dx + dy * dy + dz * dz <= r2 {
            out.push(n.index);
        }
        let delta = q[n.axis] - n.point[n.axis];
        let (near, far) = if delta < 0.0 { (&n.left, &n.right) } else { (&n.right, &n.left) };
        Self::radius_rec(near, q, r2, out);
        if delta * delta <= r2 {
            Self::radius_rec(far, q, r2, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn cloud(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n * 3).map(|_| rng.normal_f32(0.0, 5.0)).collect()
    }

    fn brute_nearest(points: &[f32], q: Vec3) -> (usize, f32) {
        let mut best = (0usize, f32::INFINITY);
        for (i, p) in points.chunks_exact(3).enumerate() {
            let d2 = (q[0] - p[0]).powi(2) + (q[1] - p[1]).powi(2) + (q[2] - p[2]).powi(2);
            if d2 < best.1 {
                best = (i, d2);
            }
        }
        best
    }

    #[test]
    fn nearest_matches_brute_force() {
        let mut rng = Rng::new(11);
        let pts = cloud(&mut rng, 500);
        let tree = KdTree::build(&pts);
        assert_eq!(tree.len(), 500);
        for _ in 0..100 {
            let q = [rng.normal_f32(0.0, 5.0), rng.normal_f32(0.0, 5.0), rng.normal_f32(0.0, 5.0)];
            let (ti, td) = tree.nearest(q).unwrap();
            let (bi, bd) = brute_nearest(&pts, q);
            assert!((td - bd).abs() < 1e-4, "dist {td} vs {bd}");
            // Indices may differ on exact ties; distances must match.
            let _ = (ti, bi);
        }
    }

    #[test]
    fn empty_tree() {
        let tree = KdTree::build(&[]);
        assert!(tree.is_empty());
        assert!(tree.nearest([0.0; 3]).is_none());
        assert!(tree.within_radius([0.0; 3], 1.0).is_empty());
    }

    #[test]
    fn member_point_is_its_own_nearest() {
        let pts = vec![0.0f32, 0.0, 0.0, 1.0, 1.0, 1.0, -2.0, 0.5, 3.0];
        let tree = KdTree::build(&pts);
        let (idx, d2) = tree.nearest([-2.0, 0.5, 3.0]).unwrap();
        assert_eq!(idx, 2);
        assert!(d2 < 1e-9);
    }

    #[test]
    fn within_radius_matches_brute() {
        let mut rng = Rng::new(12);
        let pts = cloud(&mut rng, 300);
        let tree = KdTree::build(&pts);
        let q = [0.0f32, 0.0, 0.0];
        let r = 4.0f32;
        let mut got = tree.within_radius(q, r);
        got.sort();
        let mut want: Vec<usize> = pts
            .chunks_exact(3)
            .enumerate()
            .filter(|(_, p)| p[0] * p[0] + p[1] * p[1] + p[2] * p[2] <= r * r)
            .map(|(i, _)| i)
            .collect();
        want.sort();
        assert_eq!(got, want);
    }
}
