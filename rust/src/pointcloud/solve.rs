//! Small dense linear algebra: symmetric-3x3 Jacobi eigensolver, 3x3
//! SVD, and the Kabsch rotation solve that closes each ICP iteration.

use super::{m_det, m_mul, m_transpose, v_cross, v_norm, v_scale, Mat3, Vec3};

/// Jacobi eigendecomposition of a symmetric 3x3 matrix.
/// Returns (eigenvalues descending, eigenvectors as columns of V).
pub fn eig_sym3(a: &Mat3) -> ([f32; 3], Mat3) {
    let mut m = *a;
    let mut v = super::MAT3_ID;
    for _ in 0..32 {
        // Largest off-diagonal element.
        let (mut p, mut q, mut big) = (0usize, 1usize, m[0][1].abs());
        if m[0][2].abs() > big {
            p = 0;
            q = 2;
            big = m[0][2].abs();
        }
        if m[1][2].abs() > big {
            p = 1;
            q = 2;
            big = m[1][2].abs();
        }
        if big < 1e-12 {
            break;
        }
        // Jacobi rotation zeroing m[p][q].
        let theta = 0.5 * (m[q][q] - m[p][p]) / m[p][q];
        let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
        let c = 1.0 / (t * t + 1.0).sqrt();
        let s = t * c;
        let mut r = super::MAT3_ID;
        r[p][p] = c;
        r[q][q] = c;
        r[p][q] = s;
        r[q][p] = -s;
        m = m_mul(&m_mul(&m_transpose(&r), &m), &r);
        v = m_mul(&v, &r);
    }
    let mut vals = [m[0][0], m[1][1], m[2][2]];
    // Sort descending, permuting V's columns alongside.
    let mut order = [0usize, 1, 2];
    order.sort_by(|&i, &j| vals[j].partial_cmp(&vals[i]).unwrap());
    let vals_sorted = [vals[order[0]], vals[order[1]], vals[order[2]]];
    let mut v_sorted = [[0f32; 3]; 3];
    for (new_col, &old_col) in order.iter().enumerate() {
        for row in 0..3 {
            v_sorted[row][new_col] = v[row][old_col];
        }
    }
    vals = vals_sorted;
    (vals, v_sorted)
}

fn col(m: &Mat3, j: usize) -> Vec3 {
    [m[0][j], m[1][j], m[2][j]]
}

fn set_col(m: &mut Mat3, j: usize, v: Vec3) {
    m[0][j] = v[0];
    m[1][j] = v[1];
    m[2][j] = v[2];
}

/// 3x3 SVD via eigendecomposition of AᵀA: A = U Σ Vᵀ with singular
/// values descending and U, V proper (right-handed where possible).
pub fn svd3(a: &Mat3) -> (Mat3, [f32; 3], Mat3) {
    let ata = m_mul(&m_transpose(a), a);
    let (evals, v) = eig_sym3(&ata);
    let sig = [
        evals[0].max(0.0).sqrt(),
        evals[1].max(0.0).sqrt(),
        evals[2].max(0.0).sqrt(),
    ];
    // U columns: u_j = A v_j / sigma_j; rank-deficient columns complete
    // the orthonormal frame via cross products (their dyad contributes
    // ~nothing to the reconstruction, so orientation is free there).
    let mut u = [[0f32; 3]; 3];
    let mut have = [false; 3];
    for j in 0..3 {
        if sig[j] > 1e-6 {
            let av = super::m_apply(a, col(&v, j));
            // |A v_j| == sigma_j up to fp noise; normalise by the actual
            // length for robustness.
            set_col(&mut u, j, v_scale(av, 1.0 / v_norm(av).max(1e-12)));
            have[j] = true;
        }
    }
    for j in 0..3 {
        if !have[j] {
            let (a1, a2) = ((j + 1) % 3, (j + 2) % 3);
            let filled = have[a1] && have[a2];
            let c = if filled {
                v_cross(col(&u, a1), col(&u, a2))
            } else {
                // Wholly degenerate: pick any axis not yet used.
                [[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]][j]
            };
            let n = v_norm(c);
            set_col(&mut u, j, if n > 1e-9 { v_scale(c, 1.0 / n) } else { [0.0, 0.0, 1.0] });
            have[j] = true;
        }
    }
    (u, sig, v)
}

/// Kabsch: the rotation R minimising Σ‖R·aᵢ − bᵢ‖² given the
/// cross-covariance H = Σ aᵢ bᵢᵀ (centered clouds). Handles reflections.
pub fn kabsch_rotation(h: &Mat3) -> Mat3 {
    // H = U Σ Vᵀ ⇒ R = V D Uᵀ with D = diag(1, 1, det(V Uᵀ)).
    let (u, _sig, v) = svd3(h);
    let mut vut = m_mul(&v, &m_transpose(&u));
    let d = m_det(&vut);
    if d < 0.0 {
        // Flip V's last column (smallest singular value).
        let mut v2 = v;
        set_col(&mut v2, 2, v_scale(col(&v, 2), -1.0));
        vut = m_mul(&v2, &m_transpose(&u));
    }
    vut
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pointcloud::{m_apply, rot_z, v_sub, MAT3_ID};
    use crate::util::Rng;

    fn random_rotation(rng: &mut Rng) -> Mat3 {
        // Compose rotations about z and a tilted axis for generality.
        let a = rot_z(rng.range_f64(-3.0, 3.0) as f32);
        let theta = rng.range_f64(-1.0, 1.0) as f32;
        let (s, c) = theta.sin_cos();
        let rx: Mat3 = [[1.0, 0.0, 0.0], [0.0, c, -s], [0.0, s, c]];
        m_mul(&a, &rx)
    }

    #[test]
    fn eig_identity() {
        let (vals, _) = eig_sym3(&MAT3_ID);
        for v in vals {
            assert!((v - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn eig_diagonal_sorted() {
        let d: Mat3 = [[2.0, 0.0, 0.0], [0.0, 5.0, 0.0], [0.0, 0.0, 3.0]];
        let (vals, v) = eig_sym3(&d);
        assert!((vals[0] - 5.0).abs() < 1e-5);
        assert!((vals[1] - 3.0).abs() < 1e-5);
        assert!((vals[2] - 2.0).abs() < 1e-5);
        // Eigenvector for 5 is e1.
        assert!(v[1][0].abs() > 0.99);
    }

    #[test]
    fn eig_reconstructs_matrix() {
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            // Random symmetric matrix.
            let mut a = [[0f32; 3]; 3];
            for i in 0..3 {
                for j in i..3 {
                    let x = rng.normal_f32(0.0, 1.0);
                    a[i][j] = x;
                    a[j][i] = x;
                }
            }
            let (vals, v) = eig_sym3(&a);
            // A v_j = lambda_j v_j
            for j in 0..3 {
                let av = m_apply(&a, [v[0][j], v[1][j], v[2][j]]);
                let lv = v_scale([v[0][j], v[1][j], v[2][j]], vals[j]);
                assert!(v_norm(v_sub(av, lv)) < 1e-3, "eigpair {j}: {av:?} vs {lv:?}");
            }
        }
    }

    #[test]
    fn svd_reconstructs() {
        let mut rng = Rng::new(4);
        for _ in 0..20 {
            let mut a = [[0f32; 3]; 3];
            for row in a.iter_mut() {
                for x in row.iter_mut() {
                    *x = rng.normal_f32(0.0, 1.0);
                }
            }
            let (u, s, v) = svd3(&a);
            // A ≈ U Σ Vᵀ  (allow sign slack on the last column pair by
            // comparing |A x| for random x instead of entries).
            let mut sig = [[0f32; 3]; 3];
            for i in 0..3 {
                sig[i][i] = s[i];
            }
            let recon = m_mul(&m_mul(&u, &sig), &m_transpose(&v));
            // Reconstruction may differ in sign structure only when the
            // matrix is near-singular; use a generous norm check.
            let mut err = 0f32;
            let mut mag = 0f32;
            for i in 0..3 {
                for j in 0..3 {
                    err += (recon[i][j] - a[i][j]).powi(2);
                    mag += a[i][j].powi(2);
                }
            }
            assert!(err < 0.05 * mag + 1e-3, "recon err {err} vs mag {mag}");
        }
    }

    #[test]
    fn kabsch_recovers_random_rotations() {
        let mut rng = Rng::new(5);
        for _ in 0..20 {
            let r_true = random_rotation(&mut rng);
            // Build H = sum a_i b_i^T with b = R a.
            let mut h = [[0f32; 3]; 3];
            for _ in 0..50 {
                let a =
                    [rng.normal_f32(0.0, 1.0), rng.normal_f32(0.0, 1.0), rng.normal_f32(0.0, 1.0)];
                let b = m_apply(&r_true, a);
                for i in 0..3 {
                    for j in 0..3 {
                        h[i][j] += a[i] * b[j];
                    }
                }
            }
            let r = kabsch_rotation(&h);
            for i in 0..3 {
                for j in 0..3 {
                    assert!(
                        (r[i][j] - r_true[i][j]).abs() < 2e-3,
                        "R mismatch at ({i},{j}): {r:?} vs {r_true:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn kabsch_output_is_rotation() {
        let mut rng = Rng::new(6);
        for _ in 0..20 {
            let mut h = [[0f32; 3]; 3];
            for row in h.iter_mut() {
                for x in row.iter_mut() {
                    *x = rng.normal_f32(0.0, 2.0);
                }
            }
            let r = kabsch_rotation(&h);
            let rtr = m_mul(&m_transpose(&r), &r);
            for i in 0..3 {
                for j in 0..3 {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((rtr[i][j] - want).abs() < 1e-3, "not orthonormal: {rtr:?}");
                }
            }
            assert!((m_det(&r) - 1.0).abs() < 1e-3, "det {}", m_det(&r));
        }
    }
}
