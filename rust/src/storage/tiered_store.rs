//! The Alluxio-analog memory-centric tiered block store.
//!
//! Three tiers — MEM, SSD, HDD — sit above a durable [`UnderStore`].
//! Blocks land in MEM, cascade downward under capacity pressure
//! (victims chosen by the configured [`EvictionPolicy`]), are promoted
//! back to MEM on read, and are *asynchronously* persisted to the
//! under-store, so the write path runs at memory speed (the paper's
//! section 2.2 mechanism; in its words, "the Memory layer ... serves as
//! the top level cache, SSD ... second level, HDD ... third level,
//! while persistent storage is the last level storage").
//!
//! Blocks evicted out of the tier stack entirely remain recoverable:
//! from the under-store if the async persist landed, else through the
//! lineage registry (Tachyon-style recomputation).

use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::device::DeviceModel;
use super::evict::{BlockMeta, EvictionPolicy};
use super::lineage::LineageRegistry;
use super::persist::AsyncPersister;
use super::understore::UnderStore;
use crate::config::StorageConfig;
use crate::metrics::MetricsRegistry;

pub const TIER_NAMES: [&str; 3] = ["mem", "ssd", "hdd"];

struct Entry {
    meta: BlockMeta,
    data: Arc<Vec<u8>>,
}

struct Inner {
    entries: HashMap<String, Entry>,
    used: [u64; 3],
}

/// The tiered store. Cheap to clone (Arc inside); thread-safe.
pub struct TieredStore {
    tiers: [Arc<DeviceModel>; 3],
    caps: [u64; 3],
    inner: Mutex<Inner>,
    seq: AtomicU64,
    policy: EvictionPolicy,
    under: Arc<UnderStore>,
    persister: AsyncPersister,
    lineage: LineageRegistry,
    metrics: MetricsRegistry,
}

impl TieredStore {
    pub fn new(
        cfg: &StorageConfig,
        under: Arc<UnderStore>,
        policy: EvictionPolicy,
        metrics: MetricsRegistry,
    ) -> Arc<Self> {
        let enforce = cfg.model_devices;
        Arc::new(Self {
            tiers: [
                Arc::new(DeviceModel::new(cfg.mem.clone(), enforce)),
                Arc::new(DeviceModel::new(cfg.ssd.clone(), enforce)),
                Arc::new(DeviceModel::new(cfg.hdd.clone(), enforce)),
            ],
            caps: [cfg.mem.capacity_bytes, cfg.ssd.capacity_bytes, cfg.hdd.capacity_bytes],
            inner: Mutex::new(Inner { entries: HashMap::new(), used: [0; 3] }),
            seq: AtomicU64::new(0),
            policy,
            persister: AsyncPersister::new(under.clone()),
            under,
            lineage: LineageRegistry::new(),
            metrics,
        })
    }

    /// Build a throwaway store for tests.
    pub fn test_store(cfg: &StorageConfig) -> Arc<Self> {
        let under = UnderStore::temp("tiered", cfg.dfs.clone(), cfg.model_devices).unwrap();
        Self::new(cfg, under, EvictionPolicy::Lru, MetricsRegistry::new())
    }

    pub fn lineage(&self) -> &LineageRegistry {
        &self.lineage
    }

    pub fn under(&self) -> &Arc<UnderStore> {
        &self.under
    }

    pub fn tier_device(&self, tier: usize) -> &DeviceModel {
        &self.tiers[tier]
    }

    fn next_seq(&self) -> u64 {
        self.seq.fetch_add(1, Ordering::Relaxed)
    }

    /// Write a block (lands in MEM, async-persists to the under-store).
    pub fn put(&self, key: &str, bytes: Vec<u8>) -> Result<()> {
        self.put_opts(key, bytes, false, true)
    }

    /// Write with explicit pinning / persistence control.
    pub fn put_opts(&self, key: &str, bytes: Vec<u8>, pin: bool, persist: bool) -> Result<()> {
        let size = bytes.len() as u64;
        if size > self.caps[0].max(self.caps[1]).max(self.caps[2]) {
            bail!("block '{key}' ({size} B) exceeds every tier capacity");
        }
        let data = Arc::new(bytes);
        // Memory-speed write path: charge the MEM device only.
        self.tiers[0].charge(size);
        self.metrics.counter("storage.tiered.puts").inc();

        let mut spill: Vec<(String, Arc<Vec<u8>>, bool)> = Vec::new();
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(old) = inner.entries.remove(key) {
                inner.used[old.meta.tier] -= old.meta.size;
            }
            let seq = self.next_seq();
            inner.entries.insert(
                key.to_string(),
                Entry {
                    meta: BlockMeta {
                        size,
                        tier: 0,
                        pinned: pin,
                        last_seq: seq,
                        hits: 0,
                        crf: 1.0,
                    },
                    data: data.clone(),
                },
            );
            inner.used[0] += size;
            self.make_room(&mut inner, &mut spill)?;
        }
        self.handle_spill(spill);
        if persist {
            self.persister.submit(key.to_string(), data)?;
        }
        Ok(())
    }

    /// Cascade over-capacity tiers downward; blocks leaving HDD are
    /// collected into `spill` for under-store write-back outside the lock.
    fn make_room(
        &self,
        inner: &mut Inner,
        spill: &mut Vec<(String, Arc<Vec<u8>>, bool)>,
    ) -> Result<()> {
        for tier in 0..3 {
            while inner.used[tier] > self.caps[tier] {
                let now = self.seq.load(Ordering::Relaxed);
                let victim = self
                    .policy
                    .choose(
                        inner
                            .entries
                            .iter()
                            .filter(|(_, e)| e.meta.tier == tier && !e.meta.pinned)
                            .map(|(k, e)| (k, &e.meta)),
                        now,
                    )
                    .ok_or_else(|| {
                        anyhow!("tier {} over capacity with only pinned blocks", TIER_NAMES[tier])
                    })?;
                let entry = inner.entries.get_mut(&victim).unwrap();
                let size = entry.meta.size;
                inner.used[tier] -= size;
                self.metrics
                    .counter(&format!("storage.tiered.evict.{}", TIER_NAMES[tier]))
                    .inc();
                if tier + 1 < 3 {
                    // Demote one level: charge the destination device.
                    let entry = inner.entries.get_mut(&victim).unwrap();
                    entry.meta.tier = tier + 1;
                    inner.used[tier + 1] += size;
                    self.tiers[tier + 1].charge(size);
                } else {
                    // Falls out of the stack: write back to under-store
                    // (unless the async persist already has it queued).
                    let entry = inner.entries.remove(&victim).unwrap();
                    spill.push((victim, entry.data, true));
                }
            }
        }
        Ok(())
    }

    fn handle_spill(&self, spill: Vec<(String, Arc<Vec<u8>>, bool)>) {
        for (key, data, _) in spill {
            self.metrics.counter("storage.tiered.writeback").inc();
            let _ = self.persister.submit(key, data);
        }
    }

    /// Read a block; promotes to MEM on hit in a lower tier; falls back
    /// to the under-store, then to lineage recomputation.
    pub fn get(&self, key: &str) -> Result<Arc<Vec<u8>>> {
        let mut promote_spill = Vec::new();
        let found = {
            let mut inner = self.inner.lock().unwrap();
            match inner.entries.get_mut(key) {
                Some(entry) => {
                    let seq = self.next_seq();
                    self.policy.on_access(&mut entry.meta, seq);
                    let tier = entry.meta.tier;
                    let size = entry.meta.size;
                    let data = entry.data.clone();
                    self.metrics
                        .counter(&format!("storage.tiered.hit.{}", TIER_NAMES[tier]))
                        .inc();
                    if tier != 0 {
                        // Promote to MEM (Alluxio moves hot blocks up).
                        entry.meta.tier = 0;
                        inner.used[tier] -= size;
                        inner.used[0] += size;
                        self.make_room(&mut inner, &mut promote_spill)?;
                    }
                    Some((tier, size, data))
                }
                None => None,
            }
        };
        self.handle_spill(promote_spill);
        if let Some((tier, size, data)) = found {
            // Device cost of reading from the tier it actually lived in.
            self.tiers[tier].charge(size);
            return Ok(data);
        }
        // Miss in the stack: durable under-store?
        self.metrics.counter("storage.tiered.miss").inc();
        if self.under.contains(key) {
            let bytes = self.under.read(key)?;
            let data = Arc::new(bytes);
            self.reinsert(key, data.clone())?;
            return Ok(data);
        }
        // Last resort: lineage recomputation (Tachyon-style).
        if let Some(bytes) = self.lineage.recompute(key)? {
            self.metrics.counter("storage.tiered.lineage_recovered").inc();
            let data = Arc::new(bytes);
            self.reinsert(key, data.clone())?;
            return Ok(data);
        }
        bail!("block '{key}' not found in tiers, under-store, or lineage")
    }

    fn reinsert(&self, key: &str, data: Arc<Vec<u8>>) -> Result<()> {
        let size = data.len() as u64;
        self.tiers[0].charge(size);
        let mut spill = Vec::new();
        {
            let mut inner = self.inner.lock().unwrap();
            let seq = self.next_seq();
            inner.entries.insert(
                key.to_string(),
                Entry {
                    meta: BlockMeta {
                        size,
                        tier: 0,
                        pinned: false,
                        last_seq: seq,
                        hits: 1,
                        crf: 1.0,
                    },
                    data,
                },
            );
            inner.used[0] += size;
            self.make_room(&mut inner, &mut spill)?;
        }
        self.handle_spill(spill);
        Ok(())
    }

    pub fn contains(&self, key: &str) -> bool {
        self.inner.lock().unwrap().entries.contains_key(key) || self.under.contains(key)
    }

    /// Which tier a block currently occupies (None if only durable).
    pub fn tier_of(&self, key: &str) -> Option<usize> {
        self.inner.lock().unwrap().entries.get(key).map(|e| e.meta.tier)
    }

    pub fn pin(&self, key: &str, pinned: bool) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        match inner.entries.get_mut(key) {
            Some(e) => {
                e.meta.pinned = pinned;
                Ok(())
            }
            None => bail!("cannot pin absent block '{key}'"),
        }
    }

    pub fn delete(&self, key: &str) -> Result<()> {
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(e) = inner.entries.remove(key) {
                inner.used[e.meta.tier] -= e.meta.size;
            }
        }
        self.under.delete(key)?;
        Ok(())
    }

    /// Bytes resident per tier.
    pub fn used(&self) -> [u64; 3] {
        self.inner.lock().unwrap().used
    }

    /// Wait for all queued async persists to hit the under-store.
    pub fn flush(&self) {
        self.persister.drain();
    }

    pub fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PlatformConfig, StorageConfig, TierConfig};

    fn small_cfg(mem: u64, ssd: u64, hdd: u64) -> StorageConfig {
        StorageConfig {
            mem: TierConfig { capacity_bytes: mem, bandwidth_bps: 1e12, latency_us: 0 },
            ssd: TierConfig { capacity_bytes: ssd, bandwidth_bps: 1e12, latency_us: 0 },
            hdd: TierConfig { capacity_bytes: hdd, bandwidth_bps: 1e12, latency_us: 0 },
            dfs: TierConfig { capacity_bytes: u64::MAX, bandwidth_bps: 1e12, latency_us: 0 },
            model_devices: false,
        }
    }

    #[test]
    fn put_get_roundtrip() {
        let s = TieredStore::test_store(&PlatformConfig::test().storage);
        s.put("k", vec![1, 2, 3]).unwrap();
        assert_eq!(*s.get("k").unwrap(), vec![1, 2, 3]);
        assert_eq!(s.tier_of("k"), Some(0));
    }

    #[test]
    fn eviction_cascades_down_tiers() {
        let s = TieredStore::test_store(&small_cfg(100, 100, 1000));
        s.put("a", vec![0u8; 80]).unwrap();
        s.put("b", vec![1u8; 80]).unwrap(); // evicts a to ssd
        assert_eq!(s.tier_of("b"), Some(0));
        assert_eq!(s.tier_of("a"), Some(1));
        s.put("c", vec![2u8; 80]).unwrap(); // b->ssd, a->hdd
        assert_eq!(s.tier_of("a"), Some(2));
        assert_eq!(s.tier_of("b"), Some(1));
        assert_eq!(s.tier_of("c"), Some(0));
    }

    #[test]
    fn read_promotes_to_mem() {
        let s = TieredStore::test_store(&small_cfg(100, 1000, 1000));
        s.put("a", vec![0u8; 80]).unwrap();
        s.put("b", vec![1u8; 80]).unwrap();
        assert_eq!(s.tier_of("a"), Some(1));
        let _ = s.get("a").unwrap();
        assert_eq!(s.tier_of("a"), Some(0));
        assert_eq!(s.tier_of("b"), Some(1)); // displaced by promotion
    }

    #[test]
    fn spill_past_hdd_recovers_from_under_store() {
        let s = TieredStore::test_store(&small_cfg(64, 64, 64));
        s.put("a", vec![7u8; 60]).unwrap();
        s.put("b", vec![8u8; 60]).unwrap();
        s.put("c", vec![9u8; 60]).unwrap();
        s.put("d", vec![10u8; 60]).unwrap(); // a falls out of the stack
        s.flush();
        assert_eq!(s.tier_of("a"), None);
        assert_eq!(*s.get("a").unwrap(), vec![7u8; 60]); // from under-store
        assert_eq!(s.tier_of("a"), Some(0)); // reinserted hot
    }

    #[test]
    fn pinned_blocks_never_evicted() {
        let s = TieredStore::test_store(&small_cfg(100, 1000, 1000));
        s.put_opts("keep", vec![0u8; 80], true, true).unwrap();
        s.put("other", vec![1u8; 80]).unwrap();
        assert_eq!(s.tier_of("keep"), Some(0));
        assert_eq!(s.tier_of("other"), Some(1));
    }

    #[test]
    fn oversized_block_rejected() {
        let s = TieredStore::test_store(&small_cfg(10, 10, 10));
        assert!(s.put("big", vec![0u8; 100]).is_err());
    }

    #[test]
    fn lineage_recovers_lost_block() {
        let s = TieredStore::test_store(&small_cfg(1000, 1000, 1000));
        s.lineage().register("derived", || Ok(b"recomputed".to_vec()));
        assert_eq!(*s.get("derived").unwrap(), b"recomputed".to_vec());
        // Now resident; second read is a tier hit.
        assert_eq!(s.tier_of("derived"), Some(0));
    }

    #[test]
    fn delete_removes_everywhere() {
        let s = TieredStore::test_store(&PlatformConfig::test().storage);
        s.put("k", vec![1]).unwrap();
        s.flush();
        s.delete("k").unwrap();
        assert!(!s.contains("k"));
        assert!(s.get("k").is_err());
    }

    #[test]
    fn used_accounting_consistent() {
        let s = TieredStore::test_store(&small_cfg(100, 100, 100));
        s.put("a", vec![0u8; 50]).unwrap();
        s.put("b", vec![0u8; 40]).unwrap();
        assert_eq!(s.used()[0], 90);
        s.delete("a").unwrap();
        assert_eq!(s.used()[0], 40);
    }

    #[test]
    fn interleaved_write_read_pressure_keeps_store_consistent() {
        // Writes continuously displace blocks downward while reads
        // promote them back up — the exact churn the ingest compactor
        // puts on the store. Capacity accounting must hold throughout
        // and every block must stay readable.
        let caps = small_cfg(300, 300, 600);
        let s = TieredStore::test_store(&caps);
        let mut rng = crate::util::Rng::new(4242);
        for i in 0..120u64 {
            let key = format!("chk/{i}");
            s.put(&key, vec![(i % 251) as u8; 60 + (i % 5) as usize]).unwrap();
            // Re-read a random earlier block: promotion under pressure.
            // (Drain the async persister first so a block that already
            // spilled past HDD is durably readable — same contract a
            // consumer relies on.)
            s.flush();
            let back = rng.below(i + 1);
            let got = s.get(&format!("chk/{back}")).unwrap();
            assert_eq!(got[0], (back % 251) as u8, "block chk/{back} corrupted");
            let used = s.used();
            assert!(used[0] <= 300 && used[1] <= 300 && used[2] <= 600, "over capacity: {used:?}");
        }
        s.flush();
        // Everything is still reachable afterwards, wherever it lives.
        for i in 0..120u64 {
            let got = s.get(&format!("chk/{i}")).unwrap();
            assert_eq!(got[0], (i % 251) as u8);
        }
    }

    #[test]
    fn lineage_recovers_evicted_then_lost_block() {
        // The compactor's recovery contract: a block pushed out of every
        // tier whose under-store copy is then lost must come back
        // through its lineage rule.
        let s = TieredStore::test_store(&small_cfg(64, 64, 64));
        s.lineage().register("derived", || Ok(vec![42u8; 60]));
        s.put("derived", vec![42u8; 60]).unwrap();
        // Push it out of the whole tier stack.
        for i in 0..3 {
            s.put(&format!("filler/{i}"), vec![i as u8; 60]).unwrap();
        }
        assert_eq!(s.tier_of("derived"), None, "block must have left the tiers");
        // Lose the durable copy too (async persist already landed it).
        s.flush();
        s.under().delete("derived").unwrap();
        let before = s.metrics().counter("storage.tiered.lineage_recovered").get();
        let got = s.get("derived").unwrap();
        assert_eq!(*got, vec![42u8; 60]);
        assert_eq!(
            s.metrics().counter("storage.tiered.lineage_recovered").get(),
            before + 1,
            "recovery must have come from lineage, not the under-store"
        );
        assert_eq!(s.tier_of("derived"), Some(0), "recovered block reinserted hot");
    }

    #[test]
    fn async_persist_reaches_under_store() {
        let s = TieredStore::test_store(&PlatformConfig::test().storage);
        for i in 0..10 {
            s.put(&format!("k{i}"), vec![i as u8; 32]).unwrap();
        }
        s.flush();
        assert_eq!(s.under().len(), 10);
    }
}
