//! The Alluxio-analog memory-centric tiered block store.
//!
//! Three tiers — MEM, SSD, HDD — sit above a durable [`UnderStore`].
//! Blocks land in MEM, cascade downward under capacity pressure
//! (victims chosen by the configured [`EvictionPolicy`]), are promoted
//! back to MEM on read, and are *asynchronously* persisted to the
//! under-store, so the write path runs at memory speed (the paper's
//! section 2.2 mechanism; in its words, "the Memory layer ... serves as
//! the top level cache, SSD ... second level, HDD ... third level,
//! while persistent storage is the last level storage").
//!
//! Blocks evicted out of the tier stack entirely remain recoverable:
//! from the under-store if the async persist landed, else through the
//! lineage registry (Tachyon-style recomputation).
//!
//! **Concurrency (the data-plane fast path).** The block map is
//! lock-striped into [`StorageConfig::shards`] shards keyed by key
//! hash; per-tier `used` accounting lives in atomics, so puts and gets
//! on different shards never serialize. Each shard keeps one ordered
//! eviction index per tier — a `BTreeSet<(rank, key)>` where `rank` is
//! [`EvictionPolicy::rank`], maintained incrementally on every
//! access — and the evictor takes the minimum across the shard minima.
//! Invariant: a non-pinned resident block appears in exactly one
//! index, `index[meta.tier]`, under its current rank; min-rank over
//! all shards is exactly the victim the old O(n) full-map scan chose,
//! so eviction order (and every workload's output) is unchanged while
//! victim selection drops from O(n) under one global lock to O(log n)
//! index ops. The pre-PR-5 path — one shard, one lock, full scan per
//! victim — is kept behind [`StorageConfig::scan_evict`] for the E17
//! A/B (`adcloud --baseline`).

use anyhow::{anyhow, bail, Result};
use std::collections::{BTreeSet, HashMap};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use super::device::DeviceModel;
use super::evict::{BlockMeta, EvictionPolicy};
use super::lineage::LineageRegistry;
use super::persist::AsyncPersister;
use super::understore::UnderStore;
use crate::config::StorageConfig;
use crate::metrics::{MetricsRegistry, StoreMetrics};
use crate::trace;

pub const TIER_NAMES: [&str; 3] = ["mem", "ssd", "hdd"];

struct Entry {
    meta: BlockMeta,
    /// The meta's [`EvictionPolicy::rank`] at its last access — the
    /// key this entry is filed under in its shard's eviction index.
    rank: u64,
    data: Arc<Vec<u8>>,
}

#[derive(Default)]
struct Shard {
    entries: HashMap<String, Entry>,
    /// Per-tier eviction index: `(rank, key)` ascending, non-pinned
    /// resident blocks only; `.first()` is this shard's best victim.
    index: [BTreeSet<(u64, String)>; 3],
}

impl Shard {
    /// File a block in its tier's eviction index (pinned blocks are
    /// never victims, so they are never indexed).
    fn index_insert(&mut self, key: &str, meta: &BlockMeta, rank: u64) {
        if !meta.pinned {
            self.index[meta.tier].insert((rank, key.to_string()));
        }
    }

    fn index_remove(&mut self, key: &str, meta: &BlockMeta, rank: u64) {
        if !meta.pinned {
            self.index[meta.tier].remove(&(rank, key.to_string()));
        }
    }
}

/// Deadline bookkeeping for blobs written with [`TieredStore::put_ttl`]:
/// ordered by absolute deadline so [`TieredStore::expire_ttl`] pops only
/// the due prefix — no scan over live keys.
#[derive(Default)]
struct TtlIndex {
    /// `(deadline_ms, key)` ascending.
    by_deadline: BTreeSet<(u64, String)>,
    /// Current deadline per key (for cancel-on-rewrite / delete).
    deadline: HashMap<String, u64>,
}

/// The tiered store. Cheap to clone (Arc inside); thread-safe.
pub struct TieredStore {
    tiers: [Arc<DeviceModel>; 3],
    caps: [u64; 3],
    shards: Vec<Mutex<Shard>>,
    used: [AtomicU64; 3],
    seq: AtomicU64,
    policy: EvictionPolicy,
    /// Baseline A/B knob: single-shard O(n) scan eviction (see module
    /// docs). Always paired with `shards.len() == 1`.
    scan_evict: bool,
    under: Arc<UnderStore>,
    persister: AsyncPersister,
    lineage: LineageRegistry,
    /// TTL deadlines (checkpoint GC's scan-free steady state).
    ttl: Mutex<TtlIndex>,
    /// Entry count mirror of `ttl` so the stores that never use TTLs
    /// pay one relaxed load, not a lock, on every put/delete.
    ttl_len: AtomicUsize,
    epoch: Instant,
    metrics: MetricsRegistry,
    m: StoreMetrics,
}

/// FNV-1a over the key: shard routing (stable, allocation-free; same
/// function as [`crate::scenario::fnv1a64`], kept local so the storage
/// layer doesn't reach upward into the scenario module).
fn key_hash(key: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in key.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl TieredStore {
    pub fn new(
        cfg: &StorageConfig,
        under: Arc<UnderStore>,
        policy: EvictionPolicy,
        metrics: MetricsRegistry,
    ) -> Arc<Self> {
        let enforce = cfg.model_devices;
        // The baseline scan path walks one flat map under one lock —
        // exactly the pre-sharding store — so it forces a single shard.
        let nshards = if cfg.scan_evict { 1 } else { cfg.shards.max(1) };
        let store = Arc::new(Self {
            tiers: [
                Arc::new(DeviceModel::new(cfg.mem.clone(), enforce)),
                Arc::new(DeviceModel::new(cfg.ssd.clone(), enforce)),
                Arc::new(DeviceModel::new(cfg.hdd.clone(), enforce)),
            ],
            caps: [cfg.mem.capacity_bytes, cfg.ssd.capacity_bytes, cfg.hdd.capacity_bytes],
            shards: (0..nshards).map(|_| Mutex::new(Shard::default())).collect(),
            used: [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)],
            seq: AtomicU64::new(0),
            policy,
            scan_evict: cfg.scan_evict,
            persister: AsyncPersister::new(under.clone()),
            under,
            lineage: LineageRegistry::new(),
            ttl: Mutex::new(TtlIndex::default()),
            ttl_len: AtomicUsize::new(0),
            epoch: Instant::now(),
            m: StoreMetrics::new(&metrics),
            metrics,
        });
        // Static tier capacities as gauges, so dashboards and the
        // watchdog can express usage as a fraction of capacity.
        for (t, name) in TIER_NAMES.iter().enumerate() {
            store.metrics.gauge(&format!("storage.tier_cap.{name}")).set(store.caps[t]);
        }
        store
    }

    /// Build a throwaway store for tests.
    pub fn test_store(cfg: &StorageConfig) -> Arc<Self> {
        let under = UnderStore::temp("tiered", cfg.dfs.clone(), cfg.model_devices).unwrap();
        Self::new(cfg, under, EvictionPolicy::Lru, MetricsRegistry::new())
    }

    pub fn lineage(&self) -> &LineageRegistry {
        &self.lineage
    }

    pub fn under(&self) -> &Arc<UnderStore> {
        &self.under
    }

    pub fn tier_device(&self, tier: usize) -> &DeviceModel {
        &self.tiers[tier]
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn next_seq(&self) -> u64 {
        self.seq.fetch_add(1, Ordering::Relaxed)
    }

    fn shard(&self, key: &str) -> &Mutex<Shard> {
        &self.shards[(key_hash(key) % self.shards.len() as u64) as usize]
    }

    /// Write a block (lands in MEM, async-persists to the under-store).
    pub fn put(&self, key: &str, bytes: Vec<u8>) -> Result<()> {
        self.put_opts(key, bytes, false, true)
    }

    /// Write with explicit pinning / persistence control.
    pub fn put_opts(&self, key: &str, bytes: Vec<u8>, pin: bool, persist: bool) -> Result<()> {
        let size = bytes.len() as u64;
        if size > self.caps[0].max(self.caps[1]).max(self.caps[2]) {
            bail!("block '{key}' ({size} B) exceeds every tier capacity");
        }
        let mut sp = trace::span("store.put", trace::Category::StoreIo);
        sp.arg("bytes", size);
        let data = Arc::new(bytes);
        // Memory-speed write path: charge the MEM device only.
        self.tiers[0].charge(size);
        self.m.puts.inc();

        let mut spill: Vec<(String, Arc<Vec<u8>>)> = Vec::new();
        {
            let mut sh = self.shard(key).lock().unwrap();
            if let Some(old) = sh.entries.remove(key) {
                sh.index_remove(key, &old.meta, old.rank);
                self.used[old.meta.tier].fetch_sub(old.meta.size, Ordering::Relaxed);
            }
            let seq = self.next_seq();
            let meta = BlockMeta {
                size,
                tier: 0,
                pinned: pin,
                last_seq: seq,
                hits: 0,
                crf: 1.0,
            };
            let rank = self.policy.rank(&meta);
            sh.index_insert(key, &meta, rank);
            sh.entries.insert(key.to_string(), Entry { meta, rank, data: data.clone() });
            self.used[0].fetch_add(size, Ordering::Relaxed);
            if self.scan_evict {
                self.make_room_scan(&mut sh, &mut spill)?;
            }
        }
        if !self.scan_evict {
            self.make_room(&mut spill)?;
        }
        self.handle_spill(spill);
        if persist {
            self.persister.submit(key.to_string(), data)?;
        }
        // A plain rewrite of a TTL'd key cancels its deadline (the new
        // blob has no expiry unless `put_ttl` re-arms one).
        self.ttl_cancel(key);
        self.refresh_tier_gauges();
        Ok(())
    }

    /// [`Self::put`] with an expiry: after `ttl` the blob is removed
    /// from every tier AND the under-store by [`Self::expire_ttl`] —
    /// checkpoint GC's steady state, with no scan over live keys.
    pub fn put_ttl(&self, key: &str, bytes: Vec<u8>, ttl: Duration) -> Result<()> {
        self.put(key, bytes)?;
        let deadline = self.now_ms().saturating_add(ttl.as_millis() as u64);
        let mut idx = self.ttl.lock().unwrap();
        if let Some(old) = idx.deadline.insert(key.to_string(), deadline) {
            idx.by_deadline.remove(&(old, key.to_string()));
        }
        idx.by_deadline.insert((deadline, key.to_string()));
        self.ttl_len.store(idx.deadline.len(), Ordering::Relaxed);
        Ok(())
    }

    /// Delete every blob whose TTL deadline has passed (pops the due
    /// prefix of the deadline index — O(expired log n), zero scanning).
    /// Returns how many were removed.
    pub fn expire_ttl(&self) -> Result<u64> {
        if self.ttl_len.load(Ordering::Relaxed) == 0 {
            return Ok(0);
        }
        let now = self.now_ms();
        let due: Vec<String> = {
            let mut idx = self.ttl.lock().unwrap();
            let mut due = Vec::new();
            while let Some((d, k)) = idx.by_deadline.iter().next().cloned() {
                if d > now {
                    break;
                }
                idx.by_deadline.remove(&(d, k.clone()));
                idx.deadline.remove(&k);
                due.push(k);
            }
            self.ttl_len.store(idx.deadline.len(), Ordering::Relaxed);
            due
        };
        let mut n = 0u64;
        for key in due {
            self.delete(&key)?;
            self.m.ttl_expired.inc();
            n += 1;
        }
        Ok(n)
    }

    /// Keys currently carrying a TTL deadline.
    pub fn ttl_pending(&self) -> usize {
        self.ttl_len.load(Ordering::Relaxed)
    }

    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    /// Drop `key`'s TTL deadline, if any (rewrites and deletes must not
    /// leave a stale deadline that would later remove a live blob).
    fn ttl_cancel(&self, key: &str) {
        if self.ttl_len.load(Ordering::Relaxed) == 0 {
            return;
        }
        let mut idx = self.ttl.lock().unwrap();
        if let Some(old) = idx.deadline.remove(key) {
            idx.by_deadline.remove(&(old, key.to_string()));
            self.ttl_len.store(idx.deadline.len(), Ordering::Relaxed);
        }
    }

    /// Refresh the `storage.tier_used.*` gauges from the atomic
    /// per-tier byte counters (three relaxed loads + stores).
    fn refresh_tier_gauges(&self) {
        let used = self.used();
        for t in 0..3 {
            self.m.tier_used[t].set(used[t]);
        }
    }

    /// Cascade over-capacity tiers downward; blocks leaving HDD are
    /// collected into `spill` for under-store write-back outside any
    /// shard lock. The fast path: no lock is held between victims, and
    /// each victim costs one cross-shard min peek + O(log n) index ops.
    fn make_room(&self, spill: &mut Vec<(String, Arc<Vec<u8>>)>) -> Result<()> {
        for tier in 0..3 {
            // The cross-shard scan is not atomic with other threads'
            // cascades: a candidate can appear in a shard we already
            // passed, or vanish mid-scan. An empty scan while still
            // over capacity is therefore only conclusive after several
            // consecutive misses — a genuinely pinned-full tier scans
            // empty every time, a transient race resolves within one
            // or two retries (the racing put evicts its own overflow).
            let mut empty_scans = 0;
            while self.used[tier].load(Ordering::Relaxed) > self.caps[tier] {
                let mut sp = trace::span("store.evict", trace::Category::StoreIo);
                sp.arg("tier", tier as u64);
                if self.evict_one(tier, spill)? {
                    empty_scans = 0;
                    continue;
                }
                if self.used[tier].load(Ordering::Relaxed) <= self.caps[tier] {
                    break;
                }
                empty_scans += 1;
                if empty_scans >= 8 {
                    bail!(
                        "tier {} over capacity with only pinned blocks",
                        TIER_NAMES[tier]
                    );
                }
                std::thread::yield_now();
            }
        }
        Ok(())
    }

    /// Evict the globally-best victim from `tier` (min rank across the
    /// shard minima — the same block the old full scan chose). Returns
    /// false when no shard has a candidate for this tier.
    fn evict_one(&self, tier: usize, spill: &mut Vec<(String, Arc<Vec<u8>>)>) -> Result<bool> {
        loop {
            let mut best: Option<(u64, String, usize)> = None;
            for (i, sh) in self.shards.iter().enumerate() {
                let sh = sh.lock().unwrap();
                if let Some((r, k)) = sh.index[tier].iter().next() {
                    if best.as_ref().map_or(true, |(br, _, _)| r < br) {
                        best = Some((*r, k.clone(), i));
                    }
                }
            }
            let Some((rank, key, si)) = best else { return Ok(false) };
            let mut sh = self.shards[si].lock().unwrap();
            // Between the peek and this lock the victim may have been
            // touched, promoted, or evicted by another thread; if so,
            // rescan rather than evicting a stale candidate.
            if !sh.index[tier].remove(&(rank, key.clone())) {
                continue;
            }
            if tier + 1 < 3 {
                // Demote one level: charge the destination device. The
                // rank is access-derived, so it travels with the block.
                let (size, rank) = {
                    let entry = sh.entries.get_mut(&key).expect("indexed entry present");
                    entry.meta.tier = tier + 1;
                    (entry.meta.size, entry.rank)
                };
                sh.index[tier + 1].insert((rank, key));
                self.used[tier].fetch_sub(size, Ordering::Relaxed);
                self.used[tier + 1].fetch_add(size, Ordering::Relaxed);
                self.tiers[tier + 1].charge(size);
            } else {
                // Falls out of the stack: write back to under-store
                // (unless the async persist already has it queued).
                let entry = sh.entries.remove(&key).expect("indexed entry present");
                self.used[tier].fetch_sub(entry.meta.size, Ordering::Relaxed);
                spill.push((key, entry.data));
            }
            self.m.evicts[tier].inc();
            return Ok(true);
        }
    }

    /// The pre-sharding eviction path, kept verbatim for the E17 A/B:
    /// every victim is found by scanning the whole (single-shard) map
    /// under the shard lock with [`EvictionPolicy::choose`].
    fn make_room_scan(
        &self,
        sh: &mut MutexGuard<'_, Shard>,
        spill: &mut Vec<(String, Arc<Vec<u8>>)>,
    ) -> Result<()> {
        for tier in 0..3 {
            while self.used[tier].load(Ordering::Relaxed) > self.caps[tier] {
                let now = self.seq.load(Ordering::Relaxed);
                let victim = self
                    .policy
                    .choose(
                        sh.entries
                            .iter()
                            .filter(|(_, e)| e.meta.tier == tier && !e.meta.pinned)
                            .map(|(k, e)| (k, &e.meta)),
                        now,
                    )
                    .ok_or_else(|| {
                        anyhow!("tier {} over capacity with only pinned blocks", TIER_NAMES[tier])
                    })?;
                let entry = sh.entries.get_mut(&victim).unwrap();
                let size = entry.meta.size;
                let rank = entry.rank;
                let meta = entry.meta.clone();
                self.used[tier].fetch_sub(size, Ordering::Relaxed);
                self.m.evicts[tier].inc();
                if tier + 1 < 3 {
                    let entry = sh.entries.get_mut(&victim).unwrap();
                    entry.meta.tier = tier + 1;
                    self.used[tier + 1].fetch_add(size, Ordering::Relaxed);
                    self.tiers[tier + 1].charge(size);
                    // Keep the index coherent even on the scan path so
                    // the two modes stay observably interchangeable.
                    sh.index_remove(&victim, &meta, rank);
                    let moved = sh.entries.get(&victim).unwrap().meta.clone();
                    sh.index_insert(&victim, &moved, rank);
                } else {
                    sh.index_remove(&victim, &meta, rank);
                    let entry = sh.entries.remove(&victim).unwrap();
                    spill.push((victim, entry.data));
                }
            }
        }
        Ok(())
    }

    fn handle_spill(&self, spill: Vec<(String, Arc<Vec<u8>>)>) {
        for (key, data) in spill {
            self.m.writeback.inc();
            let _ = self.persister.submit(key, data);
        }
    }

    /// Read a block; promotes to MEM on hit in a lower tier; falls back
    /// to the under-store, then to lineage recomputation.
    pub fn get(&self, key: &str) -> Result<Arc<Vec<u8>>> {
        let mut sp = trace::span("store.get", trace::Category::StoreIo);
        let mut promote_spill = Vec::new();
        let found = {
            let mut sh = self.shard(key).lock().unwrap();
            // First pass: mutate the entry only (promote + re-rank),
            // reporting what the index needs; second pass: re-file it.
            let hit = match sh.entries.get_mut(key) {
                None => None,
                Some(entry) => {
                    let seq = self.next_seq();
                    let tier = entry.meta.tier;
                    let size = entry.meta.size;
                    let old_rank = entry.rank;
                    let pinned = entry.meta.pinned;
                    self.policy.on_access(&mut entry.meta, seq);
                    if tier != 0 {
                        // Promote to MEM (Alluxio moves hot blocks up).
                        entry.meta.tier = 0;
                    }
                    entry.rank = self.policy.rank(&entry.meta);
                    Some((tier, size, old_rank, entry.rank, pinned, entry.data.clone()))
                }
            };
            match hit {
                None => None,
                Some((tier, size, old_rank, new_rank, pinned, data)) => {
                    if tier != 0 {
                        self.used[tier].fetch_sub(size, Ordering::Relaxed);
                        self.used[0].fetch_add(size, Ordering::Relaxed);
                    }
                    if !pinned {
                        // Re-file under the post-access rank (and tier).
                        sh.index[tier].remove(&(old_rank, key.to_string()));
                        sh.index[0].insert((new_rank, key.to_string()));
                    }
                    self.m.hits[tier].inc();
                    if tier != 0 && self.scan_evict {
                        self.make_room_scan(&mut sh, &mut promote_spill)?;
                    }
                    Some((tier, size, data))
                }
            }
        };
        if let Some((tier, _, _)) = found {
            if tier != 0 && !self.scan_evict {
                self.make_room(&mut promote_spill)?;
            }
        }
        self.handle_spill(promote_spill);
        if let Some((tier, size, data)) = found {
            // Device cost of reading from the tier it actually lived in.
            self.tiers[tier].charge(size);
            sp.arg("tier", tier as u64).arg("bytes", size);
            self.refresh_tier_gauges();
            return Ok(data);
        }
        // Miss in the stack: durable under-store?
        sp.arg("miss", 1);
        self.m.miss.inc();
        if self.under.contains(key) {
            let bytes = self.under.read(key)?;
            let data = Arc::new(bytes);
            self.reinsert(key, data.clone())?;
            return Ok(data);
        }
        // Last resort: lineage recomputation (Tachyon-style).
        if let Some(bytes) = self.lineage.recompute(key)? {
            self.m.lineage_recovered.inc();
            let data = Arc::new(bytes);
            self.reinsert(key, data.clone())?;
            return Ok(data);
        }
        bail!("block '{key}' not found in tiers, under-store, or lineage")
    }

    fn reinsert(&self, key: &str, data: Arc<Vec<u8>>) -> Result<()> {
        let size = data.len() as u64;
        self.tiers[0].charge(size);
        let mut spill = Vec::new();
        {
            let mut sh = self.shard(key).lock().unwrap();
            if let Some(old) = sh.entries.remove(key) {
                // A racing put/reinsert landed first; replace it.
                sh.index_remove(key, &old.meta, old.rank);
                self.used[old.meta.tier].fetch_sub(old.meta.size, Ordering::Relaxed);
            }
            let seq = self.next_seq();
            let meta = BlockMeta {
                size,
                tier: 0,
                pinned: false,
                last_seq: seq,
                hits: 1,
                crf: 1.0,
            };
            let rank = self.policy.rank(&meta);
            sh.index_insert(key, &meta, rank);
            sh.entries.insert(key.to_string(), Entry { meta, rank, data });
            self.used[0].fetch_add(size, Ordering::Relaxed);
            if self.scan_evict {
                self.make_room_scan(&mut sh, &mut spill)?;
            }
        }
        if !self.scan_evict {
            self.make_room(&mut spill)?;
        }
        self.handle_spill(spill);
        self.refresh_tier_gauges();
        Ok(())
    }

    pub fn contains(&self, key: &str) -> bool {
        self.shard(key).lock().unwrap().entries.contains_key(key) || self.under.contains(key)
    }

    /// Which tier a block currently occupies (None if only durable).
    pub fn tier_of(&self, key: &str) -> Option<usize> {
        self.shard(key).lock().unwrap().entries.get(key).map(|e| e.meta.tier)
    }

    pub fn pin(&self, key: &str, pinned: bool) -> Result<()> {
        let mut sh = self.shard(key).lock().unwrap();
        let (tier, rank) = match sh.entries.get_mut(key) {
            None => bail!("cannot pin absent block '{key}'"),
            Some(e) => {
                if e.meta.pinned == pinned {
                    return Ok(());
                }
                e.meta.pinned = pinned;
                (e.meta.tier, e.rank)
            }
        };
        if pinned {
            // Was evictable, now exempt.
            sh.index[tier].remove(&(rank, key.to_string()));
        } else {
            sh.index[tier].insert((rank, key.to_string()));
        }
        Ok(())
    }

    pub fn delete(&self, key: &str) -> Result<()> {
        {
            let mut sh = self.shard(key).lock().unwrap();
            if let Some(e) = sh.entries.remove(key) {
                sh.index_remove(key, &e.meta, e.rank);
                self.used[e.meta.tier].fetch_sub(e.meta.size, Ordering::Relaxed);
            }
        }
        self.under.delete(key)?;
        self.ttl_cancel(key);
        self.refresh_tier_gauges();
        Ok(())
    }

    /// Resident keys with the given prefix, across every shard and the
    /// under-store (checkpoint GC enumerates `ckpt/` through this).
    pub fn keys_with_prefix(&self, prefix: &str) -> Vec<String> {
        let mut keys: Vec<String> = self
            .shards
            .iter()
            .flat_map(|sh| {
                let sh = sh.lock().unwrap();
                sh.entries
                    .keys()
                    .filter(|k| k.starts_with(prefix))
                    .cloned()
                    .collect::<Vec<_>>()
            })
            .collect();
        keys.extend(self.under.keys_with_prefix(prefix));
        keys.sort_unstable();
        keys.dedup();
        keys
    }

    /// Bytes resident per tier.
    pub fn used(&self) -> [u64; 3] {
        [
            self.used[0].load(Ordering::Relaxed),
            self.used[1].load(Ordering::Relaxed),
            self.used[2].load(Ordering::Relaxed),
        ]
    }

    /// Wait for all queued async persists to hit the under-store.
    pub fn flush(&self) {
        self.persister.drain();
    }

    pub fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    /// Pre-resolved handles for the store's own counters (no registry
    /// lock on the put/get path; see [`StoreMetrics`]).
    pub fn counters(&self) -> &StoreMetrics {
        &self.m
    }

    /// Cross-check every shard's bookkeeping (used by the concurrency
    /// stress tests): per-tier sizes sum to the atomic `used` counters,
    /// and each non-pinned entry is filed in exactly its tier's index
    /// under its current rank. Call only while no other thread mutates
    /// the store.
    pub fn check_invariants(&self) -> Result<()> {
        let mut sums = [0u64; 3];
        for (si, sh) in self.shards.iter().enumerate() {
            let sh = sh.lock().unwrap();
            let mut indexed = 0usize;
            for (key, e) in &sh.entries {
                sums[e.meta.tier] += e.meta.size;
                if e.meta.pinned {
                    continue;
                }
                indexed += 1;
                for tier in 0..3 {
                    let present = sh.index[tier].contains(&(e.rank, key.clone()));
                    if (tier == e.meta.tier) != present {
                        bail!(
                            "shard {si}: '{key}' (tier {}, rank {}) {} index[{tier}]",
                            e.meta.tier,
                            e.rank,
                            if present { "wrongly in" } else { "missing from" },
                        );
                    }
                }
            }
            let index_total: usize = sh.index.iter().map(|ix| ix.len()).sum();
            if index_total != indexed {
                bail!(
                    "shard {si}: {index_total} index entries for {indexed} evictable blocks"
                );
            }
        }
        let used = self.used();
        if sums != used {
            bail!("entry sizes sum to {sums:?} but used counters say {used:?}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{PlatformConfig, StorageConfig, TierConfig, DEFAULT_STORE_SHARDS};

    fn small_cfg(mem: u64, ssd: u64, hdd: u64) -> StorageConfig {
        StorageConfig {
            mem: TierConfig { capacity_bytes: mem, bandwidth_bps: 1e12, latency_us: 0 },
            ssd: TierConfig { capacity_bytes: ssd, bandwidth_bps: 1e12, latency_us: 0 },
            hdd: TierConfig { capacity_bytes: hdd, bandwidth_bps: 1e12, latency_us: 0 },
            dfs: TierConfig { capacity_bytes: u64::MAX, bandwidth_bps: 1e12, latency_us: 0 },
            model_devices: false,
            shards: DEFAULT_STORE_SHARDS,
            scan_evict: false,
        }
    }

    #[test]
    fn put_get_roundtrip() {
        let s = TieredStore::test_store(&PlatformConfig::test().storage);
        s.put("k", vec![1, 2, 3]).unwrap();
        assert_eq!(*s.get("k").unwrap(), vec![1, 2, 3]);
        assert_eq!(s.tier_of("k"), Some(0));
    }

    #[test]
    fn tier_used_gauges_track_resident_bytes() {
        let s = TieredStore::test_store(&PlatformConfig::test().storage);
        s.put("g1", vec![0u8; 100]).unwrap();
        s.put("g2", vec![0u8; 50]).unwrap();
        let g = |t: &str| s.metrics().gauge(&format!("storage.tier_used.{t}")).get();
        assert_eq!(g("mem"), s.used()[0]);
        assert_eq!(g("mem"), 150);
        s.delete("g1").unwrap();
        assert_eq!(g("mem"), 50);
        assert_eq!(g("ssd"), s.used()[1]);
        assert_eq!(g("hdd"), s.used()[2]);
    }

    #[test]
    fn eviction_cascades_down_tiers() {
        let s = TieredStore::test_store(&small_cfg(100, 100, 1000));
        s.put("a", vec![0u8; 80]).unwrap();
        s.put("b", vec![1u8; 80]).unwrap(); // evicts a to ssd
        assert_eq!(s.tier_of("b"), Some(0));
        assert_eq!(s.tier_of("a"), Some(1));
        s.put("c", vec![2u8; 80]).unwrap(); // b->ssd, a->hdd
        assert_eq!(s.tier_of("a"), Some(2));
        assert_eq!(s.tier_of("b"), Some(1));
        assert_eq!(s.tier_of("c"), Some(0));
    }

    #[test]
    fn read_promotes_to_mem() {
        let s = TieredStore::test_store(&small_cfg(100, 1000, 1000));
        s.put("a", vec![0u8; 80]).unwrap();
        s.put("b", vec![1u8; 80]).unwrap();
        assert_eq!(s.tier_of("a"), Some(1));
        let _ = s.get("a").unwrap();
        assert_eq!(s.tier_of("a"), Some(0));
        assert_eq!(s.tier_of("b"), Some(1)); // displaced by promotion
    }

    #[test]
    fn spill_past_hdd_recovers_from_under_store() {
        let s = TieredStore::test_store(&small_cfg(64, 64, 64));
        s.put("a", vec![7u8; 60]).unwrap();
        s.put("b", vec![8u8; 60]).unwrap();
        s.put("c", vec![9u8; 60]).unwrap();
        s.put("d", vec![10u8; 60]).unwrap(); // a falls out of the stack
        s.flush();
        assert_eq!(s.tier_of("a"), None);
        assert_eq!(*s.get("a").unwrap(), vec![7u8; 60]); // from under-store
        assert_eq!(s.tier_of("a"), Some(0)); // reinserted hot
    }

    #[test]
    fn pinned_blocks_never_evicted() {
        let s = TieredStore::test_store(&small_cfg(100, 1000, 1000));
        s.put_opts("keep", vec![0u8; 80], true, true).unwrap();
        s.put("other", vec![1u8; 80]).unwrap();
        assert_eq!(s.tier_of("keep"), Some(0));
        assert_eq!(s.tier_of("other"), Some(1));
    }

    #[test]
    fn oversized_block_rejected() {
        let s = TieredStore::test_store(&small_cfg(10, 10, 10));
        assert!(s.put("big", vec![0u8; 100]).is_err());
    }

    #[test]
    fn lineage_recovers_lost_block() {
        let s = TieredStore::test_store(&small_cfg(1000, 1000, 1000));
        s.lineage().register("derived", || Ok(b"recomputed".to_vec()));
        assert_eq!(*s.get("derived").unwrap(), b"recomputed".to_vec());
        // Now resident; second read is a tier hit.
        assert_eq!(s.tier_of("derived"), Some(0));
    }

    #[test]
    fn delete_removes_everywhere() {
        let s = TieredStore::test_store(&PlatformConfig::test().storage);
        s.put("k", vec![1]).unwrap();
        s.flush();
        s.delete("k").unwrap();
        assert!(!s.contains("k"));
        assert!(s.get("k").is_err());
    }

    #[test]
    fn expired_ttl_blob_is_removed_everywhere() {
        let s = TieredStore::test_store(&PlatformConfig::test().storage);
        s.put_ttl("ckpt/old", vec![1, 2, 3], Duration::ZERO).unwrap();
        s.flush();
        assert_eq!(s.ttl_pending(), 1);
        let n = s.expire_ttl().unwrap();
        assert_eq!(n, 1);
        assert_eq!(s.ttl_pending(), 0);
        assert!(!s.contains("ckpt/old"));
        assert!(s.get("ckpt/old").is_err(), "under-store copy must be gone too");
        assert_eq!(s.metrics().counter("storage.tiered.ttl_expired").get(), 1);
    }

    #[test]
    fn unexpired_ttl_blob_survives_expire() {
        let s = TieredStore::test_store(&PlatformConfig::test().storage);
        s.put_ttl("ckpt/live", vec![9; 8], Duration::from_secs(3600)).unwrap();
        s.put("plain", vec![7; 8]).unwrap();
        assert_eq!(s.expire_ttl().unwrap(), 0);
        assert_eq!(*s.get("ckpt/live").unwrap(), vec![9; 8]);
        assert_eq!(*s.get("plain").unwrap(), vec![7; 8]);
        assert_eq!(s.ttl_pending(), 1, "plain puts must not enter the TTL index");
    }

    #[test]
    fn plain_rewrite_cancels_a_ttl() {
        let s = TieredStore::test_store(&PlatformConfig::test().storage);
        s.put_ttl("ckpt/a", vec![1], Duration::ZERO).unwrap();
        // A newer epoch rewrites the same key without a TTL: the stale
        // deadline must not reap the fresh blob.
        s.put("ckpt/a", vec![2]).unwrap();
        assert_eq!(s.ttl_pending(), 0);
        assert_eq!(s.expire_ttl().unwrap(), 0);
        assert_eq!(*s.get("ckpt/a").unwrap(), vec![2]);
    }

    #[test]
    fn delete_cancels_a_ttl() {
        let s = TieredStore::test_store(&PlatformConfig::test().storage);
        s.put_ttl("ckpt/b", vec![1], Duration::from_secs(3600)).unwrap();
        s.delete("ckpt/b").unwrap();
        assert_eq!(s.ttl_pending(), 0);
        assert_eq!(s.expire_ttl().unwrap(), 0);
    }

    #[test]
    fn re_arming_a_ttl_replaces_the_deadline() {
        let s = TieredStore::test_store(&PlatformConfig::test().storage);
        s.put_ttl("ckpt/c", vec![1], Duration::ZERO).unwrap();
        s.put_ttl("ckpt/c", vec![2], Duration::from_secs(3600)).unwrap();
        assert_eq!(s.ttl_pending(), 1, "one key, one deadline");
        assert_eq!(s.expire_ttl().unwrap(), 0, "the newer deadline wins");
        assert_eq!(*s.get("ckpt/c").unwrap(), vec![2]);
    }

    #[test]
    fn used_accounting_consistent() {
        let s = TieredStore::test_store(&small_cfg(100, 100, 100));
        s.put("a", vec![0u8; 50]).unwrap();
        s.put("b", vec![0u8; 40]).unwrap();
        assert_eq!(s.used()[0], 90);
        s.delete("a").unwrap();
        assert_eq!(s.used()[0], 40);
    }

    #[test]
    fn interleaved_write_read_pressure_keeps_store_consistent() {
        // Writes continuously displace blocks downward while reads
        // promote them back up — the exact churn the ingest compactor
        // puts on the store. Capacity accounting must hold throughout
        // and every block must stay readable.
        let caps = small_cfg(300, 300, 600);
        let s = TieredStore::test_store(&caps);
        let mut rng = crate::util::Rng::new(4242);
        for i in 0..120u64 {
            let key = format!("chk/{i}");
            s.put(&key, vec![(i % 251) as u8; 60 + (i % 5) as usize]).unwrap();
            // Re-read a random earlier block: promotion under pressure.
            // (Drain the async persister first so a block that already
            // spilled past HDD is durably readable — same contract a
            // consumer relies on.)
            s.flush();
            let back = rng.below(i + 1);
            let got = s.get(&format!("chk/{back}")).unwrap();
            assert_eq!(got[0], (back % 251) as u8, "block chk/{back} corrupted");
            let used = s.used();
            assert!(used[0] <= 300 && used[1] <= 300 && used[2] <= 600, "over capacity: {used:?}");
        }
        s.flush();
        // Everything is still reachable afterwards, wherever it lives.
        for i in 0..120u64 {
            let got = s.get(&format!("chk/{i}")).unwrap();
            assert_eq!(got[0], (i % 251) as u8);
        }
        s.check_invariants().unwrap();
    }

    #[test]
    fn lineage_recovers_evicted_then_lost_block() {
        // The compactor's recovery contract: a block pushed out of every
        // tier whose under-store copy is then lost must come back
        // through its lineage rule.
        let s = TieredStore::test_store(&small_cfg(64, 64, 64));
        s.lineage().register("derived", || Ok(vec![42u8; 60]));
        s.put("derived", vec![42u8; 60]).unwrap();
        // Push it out of the whole tier stack.
        for i in 0..3 {
            s.put(&format!("filler/{i}"), vec![i as u8; 60]).unwrap();
        }
        assert_eq!(s.tier_of("derived"), None, "block must have left the tiers");
        // Lose the durable copy too (async persist already landed it).
        s.flush();
        s.under().delete("derived").unwrap();
        let before = s.metrics().counter("storage.tiered.lineage_recovered").get();
        let got = s.get("derived").unwrap();
        assert_eq!(*got, vec![42u8; 60]);
        assert_eq!(
            s.metrics().counter("storage.tiered.lineage_recovered").get(),
            before + 1,
            "recovery must have come from lineage, not the under-store"
        );
        assert_eq!(s.tier_of("derived"), Some(0), "recovered block reinserted hot");
    }

    #[test]
    fn async_persist_reaches_under_store() {
        let s = TieredStore::test_store(&PlatformConfig::test().storage);
        for i in 0..10 {
            s.put(&format!("k{i}"), vec![i as u8; 32]).unwrap();
        }
        s.flush();
        assert_eq!(s.under().len(), 10);
    }

    #[test]
    fn sharded_and_scan_paths_evict_identically() {
        // The tentpole contract: for the LRU policy the incremental
        // index must reproduce the old full-scan eviction decisions
        // exactly — same victims, same tiers, same final layout — over
        // a randomized single-threaded workload.
        let mut sharded_cfg = small_cfg(400, 800, 1600);
        sharded_cfg.shards = 8;
        let mut scan_cfg = small_cfg(400, 800, 1600);
        scan_cfg.scan_evict = true;
        let fast = TieredStore::test_store(&sharded_cfg);
        let slow = TieredStore::test_store(&scan_cfg);
        assert_eq!(fast.shard_count(), 8);
        assert_eq!(slow.shard_count(), 1);
        let mut rng = crate::util::Rng::new(1717);
        let mut keys: Vec<String> = Vec::new();
        for op in 0..400u64 {
            // Drain both async persisters so under-store recovery (and
            // therefore every get/delete outcome) is deterministic —
            // the comparison must never depend on persist timing.
            fast.flush();
            slow.flush();
            match rng.below(10) {
                0..=5 => {
                    let key = format!("blk/{}", rng.below(60));
                    let val = vec![(op % 251) as u8; 40 + rng.below(80) as usize];
                    fast.put(&key, val.clone()).unwrap();
                    slow.put(&key, val).unwrap();
                    keys.push(key);
                }
                6..=8 if !keys.is_empty() => {
                    let key = keys[rng.below(keys.len() as u64) as usize].clone();
                    // Both stores see the identical access sequence, so
                    // their responses must match byte-for-byte.
                    let a = fast.get(&key);
                    let b = slow.get(&key);
                    match (a, b) {
                        (Ok(x), Ok(y)) => assert_eq!(x, y, "divergent data for {key}"),
                        (Err(_), Err(_)) => {}
                        (a, b) => {
                            panic!("divergent result for {key}: {:?} vs {:?}", a.is_ok(), b.is_ok())
                        }
                    }
                }
                _ if !keys.is_empty() => {
                    let key = keys[rng.below(keys.len() as u64) as usize].clone();
                    fast.delete(&key).unwrap();
                    slow.delete(&key).unwrap();
                }
                _ => {}
            }
            assert_eq!(fast.used(), slow.used(), "used diverged at op {op}");
        }
        // Final layout identical: every key on the same tier.
        keys.sort_unstable();
        keys.dedup();
        for key in &keys {
            assert_eq!(fast.tier_of(key), slow.tier_of(key), "tier diverged for {key}");
        }
        fast.check_invariants().unwrap();
        slow.check_invariants().unwrap();
    }

    #[test]
    fn lrfu_sharded_matches_scan() {
        // Same equivalence for the LRFU policy (static-rank reduction).
        let mk = |scan: bool| {
            let mut cfg = small_cfg(300, 600, 1200);
            cfg.scan_evict = scan;
            let under = UnderStore::temp("lrfu", cfg.dfs.clone(), false).unwrap();
            TieredStore::new(
                &cfg,
                under,
                EvictionPolicy::Lrfu { lambda: 0.2 },
                MetricsRegistry::new(),
            )
        };
        let fast = mk(false);
        let slow = mk(true);
        let mut rng = crate::util::Rng::new(2024);
        for op in 0..300u64 {
            // Keep under-store recovery deterministic (see the LRU
            // equivalence test).
            fast.flush();
            slow.flush();
            let key = format!("b/{}", rng.below(40));
            if rng.below(3) == 0 {
                let _ = fast.get(&key);
                let _ = slow.get(&key);
            } else {
                let val = vec![(op % 251) as u8; 50 + rng.below(50) as usize];
                fast.put(&key, val.clone()).unwrap();
                slow.put(&key, val).unwrap();
            }
        }
        for i in 0..40u64 {
            let key = format!("b/{i}");
            assert_eq!(fast.tier_of(&key), slow.tier_of(&key), "tier diverged for {key}");
        }
        assert_eq!(fast.used(), slow.used());
        fast.check_invariants().unwrap();
    }

    #[test]
    fn pin_toggle_keeps_index_coherent() {
        let s = TieredStore::test_store(&small_cfg(200, 200, 200));
        s.put("a", vec![0u8; 60]).unwrap();
        s.put("b", vec![1u8; 60]).unwrap();
        s.pin("a", true).unwrap();
        s.check_invariants().unwrap();
        // a is exempt: pressure evicts b despite a being older.
        s.put("c", vec![2u8; 60]).unwrap();
        s.put("d", vec![3u8; 60]).unwrap(); // mem 240 > 200 -> evict
        assert_eq!(s.tier_of("a"), Some(0));
        assert_eq!(s.tier_of("b"), Some(1));
        s.pin("a", false).unwrap();
        s.check_invariants().unwrap();
        // Now a (oldest) is the victim again.
        s.put("e", vec![4u8; 60]).unwrap();
        assert_eq!(s.tier_of("a"), Some(1));
        s.pin("missing", true).unwrap_err();
    }

    #[test]
    fn concurrent_put_get_promote_across_shards() {
        // The multi-threaded stress the single-lock store never had:
        // 8 writers/readers hammer overlapping key ranges across
        // shards while eviction cascades run. Afterwards the capacity
        // accounting must balance, the indexes must be coherent, and
        // every acked block must still be readable.
        let cfg = small_cfg(16 << 10, 32 << 10, 1 << 20);
        let s = TieredStore::test_store(&cfg);
        let threads = 8;
        let per_thread = 300u64;
        std::thread::scope(|scope| {
            for t in 0..threads {
                let s = s.clone();
                scope.spawn(move || {
                    let mut rng = crate::util::Rng::new(7000 + t);
                    for i in 0..per_thread {
                        // Half the keys are thread-private, half shared —
                        // shared keys force cross-thread shard contention.
                        let key = if i % 2 == 0 {
                            format!("t{t}/k{}", rng.below(64))
                        } else {
                            format!("shared/k{}", rng.below(64))
                        };
                        match rng.below(4) {
                            0..=1 => {
                                let len = 200 + rng.below(200) as usize;
                                s.put(&key, vec![(t as u8) ^ (i as u8); len]).unwrap();
                            }
                            2 => {
                                // Get promotes lower-tier hits back to MEM.
                                let _ = s.get(&key);
                            }
                            _ => {
                                let _ = s.delete(&key);
                            }
                        }
                    }
                });
            }
        });
        s.flush();
        s.check_invariants().unwrap();
        let used = s.used();
        assert!(used[0] <= cfg.mem.capacity_bytes, "mem over cap after quiesce: {used:?}");
        assert!(used[1] <= cfg.ssd.capacity_bytes, "ssd over cap after quiesce: {used:?}");
        assert!(used[2] <= cfg.hdd.capacity_bytes, "hdd over cap after quiesce: {used:?}");
        // Every block the store still claims to hold must be readable.
        for key in s.keys_with_prefix("") {
            s.get(&key).unwrap_or_else(|e| panic!("acked block {key} unreadable: {e:#}"));
        }
    }

    #[test]
    fn keys_with_prefix_spans_tiers_and_under_store() {
        let s = TieredStore::test_store(&small_cfg(64, 64, 64));
        for i in 0..4 {
            s.put(&format!("ckpt/job/{i}"), vec![9u8; 60]).unwrap();
        }
        s.put("other/x", vec![1u8; 30]).unwrap();
        s.flush(); // some ckpt blocks have spilled to the under-store
        let keys = s.keys_with_prefix("ckpt/");
        assert_eq!(keys.len(), 4, "{keys:?}");
        assert!(keys.iter().all(|k| k.starts_with("ckpt/job/")));
    }
}
