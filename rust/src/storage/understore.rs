//! Persistent under-store: the durable layer below the tier stack.
//!
//! Mirrors Alluxio's "under storage" — the system of record that the
//! memory-centric tiers asynchronously persist into. Blocks are real
//! files on disk (content-addressed by a sanitised key hash) so
//! durability is genuine, plus the remote-device model is charged on
//! every access.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::device::DeviceModel;
use crate::config::TierConfig;

/// Durable block store backed by real files.
pub struct UnderStore {
    root: PathBuf,
    device: DeviceModel,
    /// key -> file name (sequence-numbered; the map is the "namespace").
    names: Mutex<HashMap<String, String>>,
    seq: AtomicU64,
}

impl UnderStore {
    /// Create under `root` (a fresh subdirectory is made per instance).
    pub fn new(
        root: impl Into<PathBuf>,
        cfg: TierConfig,
        enforce_model: bool,
    ) -> Result<Arc<Self>> {
        let root = root.into();
        std::fs::create_dir_all(&root)
            .with_context(|| format!("creating under-store dir {root:?}"))?;
        Ok(Arc::new(Self {
            root,
            device: DeviceModel::new(cfg, enforce_model),
            names: Mutex::new(HashMap::new()),
            seq: AtomicU64::new(0),
        }))
    }

    /// A throwaway store in the system temp dir (tests, examples).
    pub fn temp(tag: &str, cfg: TierConfig, enforce_model: bool) -> Result<Arc<Self>> {
        let unique = format!(
            "adcloud-under-{tag}-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        );
        Self::new(std::env::temp_dir().join(unique), cfg, enforce_model)
    }

    pub fn write(&self, key: &str, bytes: &[u8]) -> Result<()> {
        self.device.charge(bytes.len() as u64);
        let fname = {
            let mut names = self.names.lock().unwrap();
            names
                .entry(key.to_string())
                .or_insert_with(|| format!("blk-{:08}", self.seq.fetch_add(1, Ordering::Relaxed)))
                .clone()
        };
        let path = self.root.join(fname);
        std::fs::write(&path, bytes).with_context(|| format!("writing block {key} to {path:?}"))
    }

    pub fn read(&self, key: &str) -> Result<Vec<u8>> {
        let fname = {
            let names = self.names.lock().unwrap();
            match names.get(key) {
                Some(f) => f.clone(),
                None => bail!("under-store: no block '{key}'"),
            }
        };
        let path = self.root.join(fname);
        let bytes =
            std::fs::read(&path).with_context(|| format!("reading block {key} from {path:?}"))?;
        self.device.charge(bytes.len() as u64);
        Ok(bytes)
    }

    pub fn contains(&self, key: &str) -> bool {
        self.names.lock().unwrap().contains_key(key)
    }

    pub fn delete(&self, key: &str) -> Result<()> {
        if let Some(fname) = self.names.lock().unwrap().remove(key) {
            let _ = std::fs::remove_file(self.root.join(fname));
        }
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.names.lock().unwrap().len()
    }

    /// All durable keys starting with `prefix` (checkpoint GC sweeps
    /// `ckpt/` through this).
    pub fn keys_with_prefix(&self, prefix: &str) -> Vec<String> {
        self.names
            .lock()
            .unwrap()
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect()
    }

    /// How long ago a key's blob was last written (file mtime); None if
    /// the key is absent or the filesystem hides timestamps.
    pub fn age_of(&self, key: &str) -> Option<std::time::Duration> {
        let fname = self.names.lock().unwrap().get(key)?.clone();
        let modified = std::fs::metadata(self.root.join(fname)).ok()?.modified().ok()?;
        std::time::SystemTime::now().duration_since(modified).ok()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn device(&self) -> &DeviceModel {
        &self.device
    }
}

impl Drop for UnderStore {
    fn drop(&mut self) {
        // Best-effort cleanup of temp stores.
        if self.root.starts_with(std::env::temp_dir()) {
            let _ = std::fs::remove_dir_all(&self.root);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> TierConfig {
        TierConfig { capacity_bytes: u64::MAX, bandwidth_bps: 1e9, latency_us: 0 }
    }

    #[test]
    fn write_read_roundtrip() {
        let s = UnderStore::temp("rt", cfg(), false).unwrap();
        s.write("a/b", b"hello").unwrap();
        assert_eq!(s.read("a/b").unwrap(), b"hello");
        assert!(s.contains("a/b"));
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn overwrite_replaces() {
        let s = UnderStore::temp("ow", cfg(), false).unwrap();
        s.write("k", b"v1").unwrap();
        s.write("k", b"v2").unwrap();
        assert_eq!(s.read("k").unwrap(), b"v2");
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn missing_block_errors() {
        let s = UnderStore::temp("miss", cfg(), false).unwrap();
        assert!(s.read("nope").is_err());
    }

    #[test]
    fn delete_removes() {
        let s = UnderStore::temp("del", cfg(), false).unwrap();
        s.write("k", b"v").unwrap();
        s.delete("k").unwrap();
        assert!(!s.contains("k"));
        assert!(s.read("k").is_err());
    }

    #[test]
    fn weird_keys_are_safe() {
        let s = UnderStore::temp("keys", cfg(), false).unwrap();
        for k in ["../../etc/passwd", "a b/c\nd", "", "🚗"] {
            s.write(k, k.as_bytes()).unwrap();
            assert_eq!(s.read(k).unwrap(), k.as_bytes());
        }
        assert_eq!(s.len(), 4);
    }

    #[test]
    fn device_charged_on_access() {
        let s = UnderStore::temp("dev", cfg(), false).unwrap();
        s.write("k", &[0u8; 1000]).unwrap();
        let _ = s.read("k").unwrap();
        assert_eq!(s.device().bytes_total(), 2000);
        assert_eq!(s.device().ops_total(), 2);
    }
}
