//! Asynchronous write-back to the under-store.
//!
//! Alluxio's ASYNC_THROUGH: the compute path writes at memory speed and a
//! background worker persists blocks to the durable under-store. The
//! paper relies on exactly this ("Alluxio then asynchronously persists
//! data into the remote storage nodes") for its 30X write claim.

use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use super::understore::UnderStore;

enum Job {
    Persist { key: String, bytes: Arc<Vec<u8>> },
    Shutdown,
}

/// Background persist worker.
pub struct AsyncPersister {
    tx: mpsc::Sender<Job>,
    pending: Arc<(Mutex<u64>, Condvar)>,
    errors: Arc<AtomicU64>,
    handle: Option<JoinHandle<()>>,
}

impl AsyncPersister {
    pub fn new(under: Arc<UnderStore>) -> Self {
        let (tx, rx) = mpsc::channel::<Job>();
        let pending = Arc::new((Mutex::new(0u64), Condvar::new()));
        let errors = Arc::new(AtomicU64::new(0));
        let p2 = pending.clone();
        let e2 = errors.clone();
        let handle = std::thread::Builder::new()
            .name("storage-persist".into())
            .spawn(move || {
                while let Ok(job) = rx.recv() {
                    match job {
                        Job::Shutdown => break,
                        Job::Persist { key, bytes } => {
                            if under.write(&key, &bytes).is_err() {
                                e2.fetch_add(1, Ordering::Relaxed);
                            }
                            let (lock, cv) = &*p2;
                            let mut n = lock.lock().unwrap();
                            *n -= 1;
                            cv.notify_all();
                        }
                    }
                }
            })
            .expect("spawn persist worker");
        Self { tx, pending, errors, handle: Some(handle) }
    }

    /// Queue a block for background persistence (returns immediately).
    pub fn submit(&self, key: String, bytes: Arc<Vec<u8>>) -> Result<()> {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .send(Job::Persist { key, bytes })
            .map_err(|_| anyhow::anyhow!("persist worker is gone"))
    }

    /// Block until every queued persist has been written.
    pub fn drain(&self) {
        let (lock, cv) = &*self.pending;
        let mut n = lock.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }

    pub fn pending(&self) -> u64 {
        *self.pending.0.lock().unwrap()
    }

    pub fn error_count(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }
}

impl Drop for AsyncPersister {
    fn drop(&mut self) {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TierConfig;

    fn under() -> Arc<UnderStore> {
        let cfg = TierConfig { capacity_bytes: u64::MAX, bandwidth_bps: 1e9, latency_us: 0 };
        UnderStore::temp("persist", cfg, false).unwrap()
    }

    #[test]
    fn submit_then_drain_persists() {
        let u = under();
        let p = AsyncPersister::new(u.clone());
        for i in 0..20 {
            p.submit(format!("k{i}"), Arc::new(vec![i as u8; 64])).unwrap();
        }
        p.drain();
        assert_eq!(p.pending(), 0);
        assert_eq!(u.len(), 20);
        assert_eq!(u.read("k7").unwrap(), vec![7u8; 64]);
        assert_eq!(p.error_count(), 0);
    }

    #[test]
    fn drain_on_empty_returns_immediately() {
        let p = AsyncPersister::new(under());
        p.drain();
    }

    #[test]
    fn drop_shuts_worker_down() {
        let u = under();
        {
            let p = AsyncPersister::new(u.clone());
            p.submit("k".into(), Arc::new(vec![1])).unwrap();
            p.drain();
        } // drop joins the worker
        assert!(u.contains("k"));
    }
}
