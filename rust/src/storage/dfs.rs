//! HDFS-analog baseline store.
//!
//! Every read and write goes to the remote-disk device (network hop +
//! disk bandwidth), with real file I/O underneath — the "before" side of
//! the paper's 30X (section 2.2) and 5X (section 4.2) comparisons, and
//! the inter-stage materialisation layer of the MapReduce baseline.

use anyhow::Result;
use std::sync::Arc;

use super::device::DeviceModel;
use super::understore::UnderStore;
use crate::config::TierConfig;
use crate::metrics::MetricsRegistry;

/// Disk-and-network-speed block store.
pub struct DfsStore {
    files: Arc<UnderStore>,
    metrics: MetricsRegistry,
}

impl DfsStore {
    pub fn new(
        cfg: TierConfig,
        enforce_model: bool,
        metrics: MetricsRegistry,
    ) -> Result<Arc<Self>> {
        Ok(Arc::new(Self {
            files: UnderStore::temp("dfs", cfg, enforce_model)?,
            metrics,
        }))
    }

    pub fn write(&self, key: &str, bytes: &[u8]) -> Result<()> {
        self.metrics.counter("storage.dfs.writes").inc();
        self.metrics.counter("storage.dfs.bytes_written").add(bytes.len() as u64);
        self.files.write(key, bytes)
    }

    pub fn read(&self, key: &str) -> Result<Vec<u8>> {
        self.metrics.counter("storage.dfs.reads").inc();
        let bytes = self.files.read(key)?;
        self.metrics.counter("storage.dfs.bytes_read").add(bytes.len() as u64);
        Ok(bytes)
    }

    pub fn contains(&self, key: &str) -> bool {
        self.files.contains(key)
    }

    pub fn delete(&self, key: &str) -> Result<()> {
        self.files.delete(key)
    }

    pub fn len(&self) -> usize {
        self.files.len()
    }

    pub fn is_empty(&self) -> bool {
        self.files.is_empty()
    }

    pub fn device(&self) -> &DeviceModel {
        self.files.device()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> Arc<DfsStore> {
        let cfg = TierConfig { capacity_bytes: u64::MAX, bandwidth_bps: 1e9, latency_us: 0 };
        DfsStore::new(cfg, false, MetricsRegistry::new()).unwrap()
    }

    #[test]
    fn roundtrip_and_metrics() {
        let s = store();
        s.write("x/y", &[1, 2, 3]).unwrap();
        assert_eq!(s.read("x/y").unwrap(), vec![1, 2, 3]);
        assert_eq!(s.metrics.counter("storage.dfs.writes").get(), 1);
        assert_eq!(s.metrics.counter("storage.dfs.reads").get(), 1);
        assert_eq!(s.metrics.counter("storage.dfs.bytes_read").get(), 3);
    }

    #[test]
    fn missing_key_errors() {
        assert!(store().read("ghost").is_err());
    }

    #[test]
    fn device_cost_charged_both_ways() {
        let s = store();
        s.write("k", &[0u8; 500]).unwrap();
        let _ = s.read("k").unwrap();
        assert_eq!(s.device().bytes_total(), 1000);
    }
}
