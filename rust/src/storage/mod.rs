//! Distributed storage: the Alluxio-analog tiered store (MEM/SSD/HDD +
//! async-persisted under-store + lineage recovery) and the HDFS-analog
//! DFS baseline it is benchmarked against (paper section 2.2).

pub mod device;
pub mod dfs;
pub mod evict;
pub mod lineage;
pub mod persist;
pub mod tiered_store;
pub mod understore;

pub use device::DeviceModel;
pub use dfs::DfsStore;
pub use evict::{BlockMeta, EvictionPolicy};
pub use lineage::LineageRegistry;
pub use tiered_store::TieredStore;
pub use understore::UnderStore;
