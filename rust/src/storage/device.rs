//! Storage device models.
//!
//! The paper's 30X Alluxio-vs-HDFS and 5X parameter-server results are
//! I/O-device phenomena: memory-speed reads vs disk+network round trips.
//! This repo runs on one host, so each tier applies a calibrated device
//! model (fixed per-op latency + bytes/bandwidth) as a real wait when
//! `model=true` (benches) and as virtual-cost accounting only when
//! `model=false` (unit tests). Both paths update the same counters, so
//! assertions and the virtual-time cluster simulator can read modelled
//! costs without wall-clock waits.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::config::TierConfig;

/// A modelled storage (or network) device.
#[derive(Debug)]
pub struct DeviceModel {
    pub cfg: TierConfig,
    /// Apply waits for modelled costs (benches) or account only (tests).
    pub enforce: bool,
    /// Total modelled cost ever charged, microseconds.
    modeled_us: AtomicU64,
    /// Total bytes charged.
    bytes: AtomicU64,
    /// Ops charged.
    ops: AtomicU64,
}

impl DeviceModel {
    pub fn new(cfg: TierConfig, enforce: bool) -> Self {
        Self {
            cfg,
            enforce,
            modeled_us: AtomicU64::new(0),
            bytes: AtomicU64::new(0),
            ops: AtomicU64::new(0),
        }
    }

    /// Modelled duration of one access of `bytes`.
    pub fn cost(&self, bytes: u64) -> Duration {
        let transfer_s = bytes as f64 / self.cfg.bandwidth_bps;
        Duration::from_micros(self.cfg.latency_us) + Duration::from_secs_f64(transfer_s)
    }

    /// Charge one access: account, and wait if enforcing.
    pub fn charge(&self, bytes: u64) {
        let d = self.cost(bytes);
        self.modeled_us
            .fetch_add(d.as_micros() as u64, Ordering::Relaxed);
        self.bytes.fetch_add(bytes, Ordering::Relaxed);
        self.ops.fetch_add(1, Ordering::Relaxed);
        if self.enforce {
            precise_wait(d);
        }
    }

    pub fn modeled_total(&self) -> Duration {
        Duration::from_micros(self.modeled_us.load(Ordering::Relaxed))
    }

    pub fn bytes_total(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn ops_total(&self) -> u64 {
        self.ops.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.modeled_us.store(0, Ordering::Relaxed);
        self.bytes.store(0, Ordering::Relaxed);
        self.ops.store(0, Ordering::Relaxed);
    }
}

/// Sleep for `d` with sub-millisecond accuracy: coarse sleep for the bulk,
/// spin for the tail (thread::sleep alone overshoots by ~50-100us, which
/// would swamp a 1us memory-tier model).
pub fn precise_wait(d: Duration) {
    if d.is_zero() {
        return;
    }
    let start = Instant::now();
    if d > Duration::from_micros(300) {
        std::thread::sleep(d - Duration::from_micros(200));
    }
    while start.elapsed() < d {
        std::hint::spin_loop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(latency_us: u64, bw: f64) -> TierConfig {
        TierConfig { capacity_bytes: 1 << 30, bandwidth_bps: bw, latency_us }
    }

    #[test]
    fn cost_includes_latency_and_transfer() {
        let d = DeviceModel::new(cfg(1000, 1e6), false);
        // 1ms latency + 1MB/s over 500KB = 0.5s
        let c = d.cost(500_000);
        assert!((c.as_secs_f64() - 0.501).abs() < 1e-6, "{c:?}");
    }

    #[test]
    fn accounting_without_enforcement_is_instant() {
        let d = DeviceModel::new(cfg(1_000_000, 1.0), false);
        let start = Instant::now();
        d.charge(1_000_000);
        assert!(start.elapsed() < Duration::from_millis(50));
        assert!(d.modeled_total() >= Duration::from_secs(1));
        assert_eq!(d.bytes_total(), 1_000_000);
        assert_eq!(d.ops_total(), 1);
    }

    #[test]
    fn enforcement_actually_waits() {
        let d = DeviceModel::new(cfg(2_000, 1e12), true);
        let start = Instant::now();
        d.charge(10);
        assert!(start.elapsed() >= Duration::from_micros(1_900));
    }

    #[test]
    fn precise_wait_accuracy() {
        for us in [50u64, 500, 2000] {
            let d = Duration::from_micros(us);
            let start = Instant::now();
            precise_wait(d);
            let e = start.elapsed();
            assert!(e >= d, "waited {e:?} < {d:?}");
            assert!(e < d + Duration::from_millis(2), "overshot: {e:?} for {d:?}");
        }
    }

    #[test]
    fn reset_clears_counters() {
        let d = DeviceModel::new(cfg(1, 1e9), false);
        d.charge(100);
        d.reset();
        assert_eq!(d.bytes_total(), 0);
        assert_eq!(d.ops_total(), 0);
    }
}
