//! Lineage-based block recovery (Tachyon/Alluxio's signature feature).
//!
//! Instead of replicating every block, the store remembers *how a block
//! was produced*; if it is lost from all tiers before its async persist
//! lands, it is recomputed on demand. The compute engine registers a
//! recompute closure whenever it caches an RDD partition through the
//! tiered store, which is what makes executor-crash fault injection
//! (experiment E12) recoverable.

use anyhow::Result;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

type Recompute = Arc<dyn Fn() -> Result<Vec<u8>> + Send + Sync>;

/// Registry of key -> recompute closure.
#[derive(Clone, Default)]
pub struct LineageRegistry {
    inner: Arc<Mutex<HashMap<String, Recompute>>>,
}

impl LineageRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or replace) the recompute rule for a block.
    pub fn register(&self, key: &str, f: impl Fn() -> Result<Vec<u8>> + Send + Sync + 'static) {
        self.inner.lock().unwrap().insert(key.to_string(), Arc::new(f));
    }

    /// Recompute a block if a rule exists. `Ok(None)` = no lineage known.
    pub fn recompute(&self, key: &str) -> Result<Option<Vec<u8>>> {
        let f = {
            let map = self.inner.lock().unwrap();
            map.get(key).cloned()
        };
        match f {
            Some(f) => Ok(Some(f()?)),
            None => Ok(None),
        }
    }

    pub fn forget(&self, key: &str) {
        self.inner.lock().unwrap().remove(key);
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU32, Ordering};

    #[test]
    fn recompute_runs_closure() {
        let l = LineageRegistry::new();
        l.register("k", || Ok(vec![1, 2, 3]));
        assert_eq!(l.recompute("k").unwrap(), Some(vec![1, 2, 3]));
    }

    #[test]
    fn unknown_key_is_none() {
        let l = LineageRegistry::new();
        assert_eq!(l.recompute("nope").unwrap(), None);
    }

    #[test]
    fn recompute_errors_propagate() {
        let l = LineageRegistry::new();
        l.register("bad", || anyhow::bail!("upstream data gone"));
        assert!(l.recompute("bad").is_err());
    }

    #[test]
    fn forget_removes_rule() {
        let l = LineageRegistry::new();
        l.register("k", || Ok(vec![]));
        l.forget("k");
        assert_eq!(l.recompute("k").unwrap(), None);
        assert!(l.is_empty());
    }

    #[test]
    fn closures_can_capture_state() {
        let calls = Arc::new(AtomicU32::new(0));
        let c2 = calls.clone();
        let l = LineageRegistry::new();
        l.register("counted", move || {
            c2.fetch_add(1, Ordering::SeqCst);
            Ok(vec![9])
        });
        l.recompute("counted").unwrap();
        l.recompute("counted").unwrap();
        assert_eq!(calls.load(Ordering::SeqCst), 2);
    }
}
