//! Eviction policies for the tiered store.
//!
//! Alluxio ships LRU and LRFU evictors; both are reproduced here. The
//! policy only *chooses the victim* — the cascade (MEM→SSD→HDD→under)
//! lives in [`super::tiered_store`].

/// Per-block bookkeeping the policies score on.
#[derive(Debug, Clone)]
pub struct BlockMeta {
    pub size: u64,
    pub tier: usize,
    pub pinned: bool,
    /// Monotonic sequence number of the last access.
    pub last_seq: u64,
    /// Total accesses.
    pub hits: u64,
    /// CRF accumulator for LRFU.
    pub crf: f64,
}

/// Victim-selection policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EvictionPolicy {
    /// Least-recently-used (Alluxio's default evictor).
    Lru,
    /// Least-recently/frequently-used: score = CRF with decay `lambda`
    /// in (0,1); lambda→1 behaves like LFU, lambda→0 like LRU.
    Lrfu { lambda: f64 },
}

impl EvictionPolicy {
    /// Pick the victim among `candidates` (already filtered to the tier
    /// and unpinned). Delegates to [`Self::rank`] with ties broken by
    /// key — exactly the ordering of the tiered store's per-tier
    /// `BTreeSet<(rank, key)>` index, so the O(n) scan and the index
    /// can never disagree on a victim.
    pub fn choose<'a>(
        &self,
        candidates: impl Iterator<Item = (&'a String, &'a BlockMeta)>,
        _now_seq: u64,
    ) -> Option<String> {
        candidates.min_by_key(|(k, m)| (self.rank(m), (*k).clone())).map(|(k, _)| k.clone())
    }

    /// Static eviction rank for the tiered store's ordered per-tier
    /// index: among any candidate set the block with the SMALLEST rank
    /// is the victim, and the rank depends only on the block's own
    /// metadata — never on `now` — so the index only needs updating
    /// when a block is accessed.
    ///
    /// Scoring is size-aware — victims are ranked per byte, so one big
    /// cold block is reclaimed before many small ones that free less
    /// space for the same recency. With uniform sizes the order reduces
    /// exactly to the plain recency/frequency order.
    ///
    /// LRU: rank = `last_seq / size` (oldest-per-byte = smallest); a
    /// division by a shared constant is order-preserving, so uniform
    /// sizes reproduce the pure `last_seq` order.
    /// LRFU: the score `crf * (1-λ)^(now-last_seq) / size` shares the
    /// positive factor `(1-λ)^now` across all candidates, so the
    /// ordering is the ordering of
    /// `ln(crf) - last_seq * ln(1-λ) - ln(size)` — a static key. The
    /// `64·ln 2` offset keeps the key non-negative (`size <= 2^64`, so
    /// `ln(size) <= 64·ln 2`; the other terms are non-negative since
    /// `crf >= 1` and `ln(1-λ) < 0`), which keeps the IEEE bit pattern
    /// of the f64 monotonically ordered in the same `u64` index.
    pub fn rank(&self, meta: &BlockMeta) -> u64 {
        let size = meta.size.max(1) as f64;
        match self {
            EvictionPolicy::Lru => (meta.last_seq as f64 / size).to_bits(),
            EvictionPolicy::Lrfu { lambda } => {
                let decay = (1.0 - lambda).clamp(1e-12, 1.0 - 1e-12);
                let key = meta.crf.max(1.0).ln() + meta.last_seq as f64 * -decay.ln()
                    - size.ln()
                    + 64.0 * std::f64::consts::LN_2;
                key.max(0.0).to_bits()
            }
        }
    }

    /// Update a block's CRF on access (LRFU bookkeeping; harmless for LRU).
    pub fn on_access(&self, meta: &mut BlockMeta, now_seq: u64) {
        if let EvictionPolicy::Lrfu { lambda } = self {
            let age = now_seq.saturating_sub(meta.last_seq) as f64;
            meta.crf = 1.0 + meta.crf * (1.0 - lambda).powf(age);
        }
        meta.last_seq = now_seq;
        meta.hits += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn meta(last_seq: u64, hits: u64) -> BlockMeta {
        BlockMeta { size: 1, tier: 0, pinned: false, last_seq, hits, crf: hits as f64 }
    }

    #[test]
    fn lru_picks_oldest() {
        let mut m = HashMap::new();
        m.insert("a".to_string(), meta(5, 1));
        m.insert("b".to_string(), meta(2, 10));
        m.insert("c".to_string(), meta(9, 1));
        let victim = EvictionPolicy::Lru.choose(m.iter(), 10).unwrap();
        assert_eq!(victim, "b");
    }

    #[test]
    fn lrfu_prefers_cold_and_rare() {
        let mut m = HashMap::new();
        // hot: recently + frequently used; cold: old and rarely used.
        m.insert("hot".to_string(), meta(99, 50));
        m.insert("cold".to_string(), meta(10, 1));
        let victim = EvictionPolicy::Lrfu { lambda: 0.1 }.choose(m.iter(), 100).unwrap();
        assert_eq!(victim, "cold");
    }

    #[test]
    fn empty_candidates_none() {
        let m: HashMap<String, BlockMeta> = HashMap::new();
        assert!(EvictionPolicy::Lru.choose(m.iter(), 0).is_none());
    }

    #[test]
    fn rank_agrees_with_choose_for_lru_and_lrfu() {
        // The incremental index is only correct if min-rank always
        // names the block the O(n) scan would have chosen.
        for policy in [
            EvictionPolicy::Lru,
            EvictionPolicy::Lrfu { lambda: 0.1 },
            EvictionPolicy::Lrfu { lambda: 0.7 },
        ] {
            let mut m = HashMap::new();
            let mut rng = crate::util::Rng::new(99);
            for i in 0..64u64 {
                let mut meta = meta(rng.below(1000), 0);
                meta.crf = 1.0 + rng.next_f32() as f64 * 40.0;
                m.insert(format!("k{i}"), meta);
            }
            for now in [1000u64, 5000] {
                let scanned = policy.choose(m.iter(), now).unwrap();
                let indexed = m
                    .iter()
                    .min_by_key(|(k, meta)| (policy.rank(meta), (*k).clone()))
                    .map(|(k, _)| k.clone())
                    .unwrap();
                assert_eq!(
                    policy.rank(&m[&scanned]),
                    policy.rank(&m[&indexed]),
                    "{policy:?} at now={now}: scan chose {scanned}, index chose {indexed}"
                );
            }
        }
    }

    #[test]
    fn size_aware_rank_prefers_one_big_cold_block() {
        // A 100 KiB block that is barely older should be evicted before
        // a 1-byte block: per byte reclaimed it is by far the colder.
        for policy in [EvictionPolicy::Lru, EvictionPolicy::Lrfu { lambda: 0.1 }] {
            let mut m = HashMap::new();
            let mut big = meta(50, 2);
            big.size = 100 << 10;
            big.crf = 2.0;
            let mut small = meta(40, 2);
            small.crf = 2.0;
            m.insert("big".to_string(), big);
            m.insert("small".to_string(), small);
            let victim = policy.choose(m.iter(), 60).unwrap();
            assert_eq!(victim, "big", "{policy:?} must rank victims per byte");
        }
    }

    #[test]
    fn uniform_sizes_match_the_pre_size_aware_order() {
        // With every block the same size, the per-byte scoring must
        // reduce to exactly the plain recency/frequency order the
        // pre-size-aware policies produced.
        let mut m = HashMap::new();
        let mut rng = crate::util::Rng::new(0x517E);
        for i in 0..48u64 {
            // Ages capped at 5000 keep the legacy oracle's direct
            // `(1-λ)^age` out of f64 underflow (0.9^age hits zero near
            // age 7100, which would tie every old block at 0.0).
            let mut b = meta(5_000 + rng.below(5_000), 1);
            b.size = 4096;
            b.crf = 1.0 + rng.next_f64() * 30.0;
            m.insert(format!("k{i}"), b);
        }
        let now = 10_000u64;

        let lru_legacy =
            m.iter().min_by_key(|(k, b)| (b.last_seq, (*k).clone())).map(|(k, _)| k.clone());
        assert_eq!(EvictionPolicy::Lru.choose(m.iter(), now), lru_legacy);

        let lambda = 0.1f64;
        let lrfu_legacy = m
            .iter()
            .map(|(k, b)| (k, b.crf * (1.0 - lambda).powf((now - b.last_seq) as f64)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(k, _)| k.clone());
        assert_eq!(EvictionPolicy::Lrfu { lambda }.choose(m.iter(), now), lrfu_legacy);
    }

    #[test]
    fn rank_is_monotonic_in_recency() {
        for policy in [EvictionPolicy::Lru, EvictionPolicy::Lrfu { lambda: 0.3 }] {
            let older = meta(10, 3);
            let newer = meta(500, 3);
            assert!(
                policy.rank(&older) < policy.rank(&newer),
                "{policy:?}: an older access must rank as a better victim"
            );
        }
    }

    #[test]
    fn on_access_updates_recency_and_crf() {
        let pol = EvictionPolicy::Lrfu { lambda: 0.5 };
        let mut m = meta(0, 0);
        m.crf = 0.0;
        pol.on_access(&mut m, 4);
        assert_eq!(m.last_seq, 4);
        assert_eq!(m.hits, 1);
        assert!(m.crf >= 1.0);
        let crf1 = m.crf;
        pol.on_access(&mut m, 5);
        assert!(m.crf > crf1);
    }
}
