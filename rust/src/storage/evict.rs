//! Eviction policies for the tiered store.
//!
//! Alluxio ships LRU and LRFU evictors; both are reproduced here. The
//! policy only *chooses the victim* — the cascade (MEM→SSD→HDD→under)
//! lives in [`super::tiered_store`].

/// Per-block bookkeeping the policies score on.
#[derive(Debug, Clone)]
pub struct BlockMeta {
    pub size: u64,
    pub tier: usize,
    pub pinned: bool,
    /// Monotonic sequence number of the last access.
    pub last_seq: u64,
    /// Total accesses.
    pub hits: u64,
    /// CRF accumulator for LRFU.
    pub crf: f64,
}

/// Victim-selection policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EvictionPolicy {
    /// Least-recently-used (Alluxio's default evictor).
    Lru,
    /// Least-recently/frequently-used: score = CRF with decay `lambda`
    /// in (0,1); lambda→1 behaves like LFU, lambda→0 like LRU.
    Lrfu { lambda: f64 },
}

impl EvictionPolicy {
    /// Pick the victim among `candidates` (already filtered to the tier
    /// and unpinned). `now_seq` is the current access counter.
    pub fn choose<'a>(
        &self,
        candidates: impl Iterator<Item = (&'a String, &'a BlockMeta)>,
        now_seq: u64,
    ) -> Option<String> {
        match self {
            EvictionPolicy::Lru => candidates
                .min_by_key(|(_, m)| m.last_seq)
                .map(|(k, _)| k.clone()),
            EvictionPolicy::Lrfu { lambda } => candidates
                .map(|(k, m)| {
                    let age = now_seq.saturating_sub(m.last_seq) as f64;
                    // Decayed combined recency/frequency value: smaller is
                    // a better victim.
                    let score = m.crf * (1.0 - lambda).powf(age);
                    (k, score)
                })
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(k, _)| k.clone()),
        }
    }

    /// Static eviction rank for the tiered store's ordered per-tier
    /// index: among any candidate set the block with the SMALLEST rank
    /// is the victim [`Self::choose`] would pick, and the rank depends
    /// only on the block's own metadata — never on `now` — so the index
    /// only needs updating when a block is accessed.
    ///
    /// LRU: rank = `last_seq` (oldest access = smallest).
    /// LRFU: the score `crf * (1-λ)^(now-last_seq)` shares the positive
    /// factor `(1-λ)^now` across all candidates, so the ordering is the
    /// ordering of `ln(crf) - last_seq * ln(1-λ)` — a static key. Both
    /// terms are non-negative (`crf >= 1`, `ln(1-λ) < 0`), so the IEEE
    /// bit pattern of the f64 is itself monotonically ordered and fits
    /// the same `u64` index.
    pub fn rank(&self, meta: &BlockMeta) -> u64 {
        match self {
            EvictionPolicy::Lru => meta.last_seq,
            EvictionPolicy::Lrfu { lambda } => {
                let decay = (1.0 - lambda).clamp(1e-12, 1.0 - 1e-12);
                let key = meta.crf.max(1.0).ln() + meta.last_seq as f64 * -decay.ln();
                key.max(0.0).to_bits()
            }
        }
    }

    /// Update a block's CRF on access (LRFU bookkeeping; harmless for LRU).
    pub fn on_access(&self, meta: &mut BlockMeta, now_seq: u64) {
        if let EvictionPolicy::Lrfu { lambda } = self {
            let age = now_seq.saturating_sub(meta.last_seq) as f64;
            meta.crf = 1.0 + meta.crf * (1.0 - lambda).powf(age);
        }
        meta.last_seq = now_seq;
        meta.hits += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn meta(last_seq: u64, hits: u64) -> BlockMeta {
        BlockMeta { size: 1, tier: 0, pinned: false, last_seq, hits, crf: hits as f64 }
    }

    #[test]
    fn lru_picks_oldest() {
        let mut m = HashMap::new();
        m.insert("a".to_string(), meta(5, 1));
        m.insert("b".to_string(), meta(2, 10));
        m.insert("c".to_string(), meta(9, 1));
        let victim = EvictionPolicy::Lru.choose(m.iter(), 10).unwrap();
        assert_eq!(victim, "b");
    }

    #[test]
    fn lrfu_prefers_cold_and_rare() {
        let mut m = HashMap::new();
        // hot: recently + frequently used; cold: old and rarely used.
        m.insert("hot".to_string(), meta(99, 50));
        m.insert("cold".to_string(), meta(10, 1));
        let victim = EvictionPolicy::Lrfu { lambda: 0.1 }.choose(m.iter(), 100).unwrap();
        assert_eq!(victim, "cold");
    }

    #[test]
    fn empty_candidates_none() {
        let m: HashMap<String, BlockMeta> = HashMap::new();
        assert!(EvictionPolicy::Lru.choose(m.iter(), 0).is_none());
    }

    #[test]
    fn rank_agrees_with_choose_for_lru_and_lrfu() {
        // The incremental index is only correct if min-rank always
        // names the block the O(n) scan would have chosen.
        for policy in [
            EvictionPolicy::Lru,
            EvictionPolicy::Lrfu { lambda: 0.1 },
            EvictionPolicy::Lrfu { lambda: 0.7 },
        ] {
            let mut m = HashMap::new();
            let mut rng = crate::util::Rng::new(99);
            for i in 0..64u64 {
                let mut meta = meta(rng.below(1000), 0);
                meta.crf = 1.0 + rng.next_f32() as f64 * 40.0;
                m.insert(format!("k{i}"), meta);
            }
            for now in [1000u64, 5000] {
                let scanned = policy.choose(m.iter(), now).unwrap();
                let indexed = m
                    .iter()
                    .min_by_key(|(k, meta)| (policy.rank(meta), (*k).clone()))
                    .map(|(k, _)| k.clone())
                    .unwrap();
                assert_eq!(
                    policy.rank(&m[&scanned]),
                    policy.rank(&m[&indexed]),
                    "{policy:?} at now={now}: scan chose {scanned}, index chose {indexed}"
                );
            }
        }
    }

    #[test]
    fn rank_is_monotonic_in_recency() {
        for policy in [EvictionPolicy::Lru, EvictionPolicy::Lrfu { lambda: 0.3 }] {
            let older = meta(10, 3);
            let newer = meta(500, 3);
            assert!(
                policy.rank(&older) < policy.rank(&newer),
                "{policy:?}: an older access must rank as a better victim"
            );
        }
    }

    #[test]
    fn on_access_updates_recency_and_crf() {
        let pol = EvictionPolicy::Lrfu { lambda: 0.5 };
        let mut m = meta(0, 0);
        m.crf = 0.0;
        pol.on_access(&mut m, 4);
        assert_eq!(m.last_seq, 4);
        assert_eq!(m.hits, 1);
        assert!(m.crf >= 1.0);
        let crf1 = m.crf;
        pol.on_access(&mut m, 5);
        assert!(m.crf > crf1);
    }
}
