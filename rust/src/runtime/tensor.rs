//! Host tensors crossing the runtime channel boundary.
//!
//! The PJRT client types (`xla::PjRtClient`, `Literal`) are `Rc`-backed
//! and must stay on their device-server thread; [`Tensor`] is the plain
//! `Send` host-side value the rest of the platform traffics in.

use anyhow::{anyhow, bail, Result};

use super::xla_stub as xla;

/// Element storage for the two dtypes the artifacts use.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A dense host tensor (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn from_f32(data: Vec<f32>, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            bail!("f32 tensor: {} elements for shape {:?}", data.len(), shape);
        }
        Ok(Self { shape: shape.to_vec(), data: TensorData::F32(data) })
    }

    pub fn from_i32(data: Vec<i32>, shape: &[usize]) -> Result<Self> {
        let n: usize = shape.iter().product();
        if data.len() != n {
            bail!("i32 tensor: {} elements for shape {:?}", data.len(), shape);
        }
        Ok(Self { shape: shape.to_vec(), data: TensorData::I32(data) })
    }

    pub fn scalar_f32(v: f32) -> Self {
        Self { shape: vec![], data: TensorData::F32(vec![v]) }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Self { shape: shape.to_vec(), data: TensorData::F32(vec![0.0; n]) }
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.element_count() * 4
    }

    pub fn dtype_tag(&self) -> &'static str {
        match self.data {
            TensorData::F32(_) => "f32",
            TensorData::I32(_) => "s32",
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => Err(anyhow!("tensor is i32, expected f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => Err(anyhow!("tensor is f32, expected i32")),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => Err(anyhow!("tensor is i32, expected f32")),
        }
    }

    /// Scalar extraction (shape [] or [1]).
    pub fn scalar_value(&self) -> Result<f32> {
        if self.element_count() != 1 {
            bail!("not a scalar: shape {:?}", self.shape);
        }
        match &self.data {
            TensorData::F32(v) => Ok(v[0]),
            TensorData::I32(v) => Ok(v[0] as f32),
        }
    }

    /// Convert to an `xla::Literal` (device-server thread only).
    pub(crate) fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => {
                if self.shape.is_empty() {
                    return Ok(xla::Literal::scalar(v[0]));
                }
                xla::Literal::vec1(v)
            }
            TensorData::I32(v) => {
                if self.shape.is_empty() {
                    return Ok(xla::Literal::scalar(v[0]));
                }
                xla::Literal::vec1(v)
            }
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Build from an `xla::Literal` (device-server thread only).
    pub(crate) fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                Ok(Self { shape: dims, data: TensorData::F32(lit.to_vec::<f32>()?) })
            }
            xla::ElementType::S32 => {
                Ok(Self { shape: dims, data: TensorData::I32(lit.to_vec::<i32>()?) })
            }
            other => bail!("unsupported artifact output dtype {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(Tensor::from_f32(vec![1.0; 6], &[2, 3]).is_ok());
        assert!(Tensor::from_f32(vec![1.0; 5], &[2, 3]).is_err());
        assert!(Tensor::from_i32(vec![1; 4], &[4]).is_ok());
    }

    #[test]
    fn scalar_access() {
        let t = Tensor::scalar_f32(3.5);
        assert_eq!(t.scalar_value().unwrap(), 3.5);
        assert!(Tensor::zeros(&[2, 2]).scalar_value().is_err());
    }

    #[test]
    fn dtype_guards() {
        let t = Tensor::from_i32(vec![1, 2], &[2]).unwrap();
        assert!(t.as_f32().is_err());
        assert_eq!(t.as_i32().unwrap(), &[1, 2]);
        assert_eq!(t.dtype_tag(), "s32");
    }

    #[test]
    fn sizes() {
        let t = Tensor::zeros(&[4, 8]);
        assert_eq!(t.element_count(), 32);
        assert_eq!(t.size_bytes(), 128);
    }
}
