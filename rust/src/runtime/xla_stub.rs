//! In-repo stand-in for the `xla` (xla_extension / PJRT) bindings.
//!
//! This offline build has no XLA shared library, so `PjRtClient::cpu`
//! always reports an init error and the device loop degrades to
//! failing requests with a clear message (artifact-gated tests skip).
//! The API mirrors the subset `server.rs`/`tensor.rs` use, so swapping
//! the real crate back in is a one-line `use` change. Host-side
//! [`Literal`] plumbing is implemented for real: tensors round-trip
//! through it in unit tests without a device.

#![allow(dead_code)]

/// Error type mirroring `xla::Error`; converts into `anyhow::Error`
/// via `std::error::Error` so call sites can use `?`.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for XlaError {}

type XlaResult<T> = std::result::Result<T, XlaError>;

/// Element dtypes the artifacts traffic in (plus `Pred` so dtype
/// matches keep a genuine fallback arm).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    Pred,
}

#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Scalar types that can live in a [`Literal`].
pub trait NativeType: Copy {
    const TY: ElementType;
    fn wrap(v: Vec<Self>) -> LiteralData
    where
        Self: Sized;
    fn unwrap(d: &LiteralData) -> XlaResult<Vec<Self>>
    where
        Self: Sized;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;

    fn wrap(v: Vec<Self>) -> LiteralData {
        LiteralData::F32(v)
    }

    fn unwrap(d: &LiteralData) -> XlaResult<Vec<Self>> {
        match d {
            LiteralData::F32(v) => Ok(v.clone()),
            other => Err(XlaError(format!("literal is not f32: {other:?}"))),
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;

    fn wrap(v: Vec<Self>) -> LiteralData {
        LiteralData::I32(v)
    }

    fn unwrap(d: &LiteralData) -> XlaResult<Vec<Self>> {
        match d {
            LiteralData::I32(v) => Ok(v.clone()),
            other => Err(XlaError(format!("literal is not i32: {other:?}"))),
        }
    }
}

/// Host-side literal: flat element storage + dims. Fully functional.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<i64>,
}

impl Literal {
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { data: T::wrap(vec![v]), dims: vec![] }
    }

    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { data: T::wrap(v.to_vec()), dims: vec![v.len() as i64] }
    }

    fn len(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::Tuple(v) => v.len(),
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> XlaResult<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.len() {
            return Err(XlaError(format!(
                "reshape: {} elements into dims {dims:?}",
                self.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn array_shape(&self) -> XlaResult<ArrayShape> {
        let ty = match &self.data {
            LiteralData::F32(_) => ElementType::F32,
            LiteralData::I32(_) => ElementType::S32,
            LiteralData::Tuple(_) => {
                return Err(XlaError("tuple literal has no array shape".into()))
            }
        };
        Ok(ArrayShape { dims: self.dims.clone(), ty })
    }

    pub fn to_vec<T: NativeType>(&self) -> XlaResult<Vec<T>> {
        T::unwrap(&self.data)
    }

    pub fn to_tuple(&self) -> XlaResult<Vec<Literal>> {
        match &self.data {
            LiteralData::Tuple(v) => Ok(v.clone()),
            _ => Err(XlaError("literal is not a tuple".into())),
        }
    }
}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Parsed HLO module (opaque in the stub).
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> XlaResult<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| XlaError(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

pub struct XlaComputation {
    _hlo: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _hlo: proto.text.clone() }
    }
}

const NO_RUNTIME: &str =
    "XLA/PJRT runtime not linked in this build; numeric artifacts are unavailable";

/// PJRT client stand-in. `cpu()` reports the runtime as unavailable,
/// which the device loop already handles by failing each request with
/// a clear error (and artifact-gated tests skip).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> XlaResult<PjRtClient> {
        Err(XlaError(NO_RUNTIME.into()))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> XlaResult<PjRtLoadedExecutable> {
        Err(XlaError(NO_RUNTIME.into()))
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> XlaResult<Vec<Vec<PjRtBuffer>>> {
        Err(XlaError(NO_RUNTIME.into()))
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> XlaResult<Literal> {
        Err(XlaError(NO_RUNTIME.into()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trips_without_a_device() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
        assert!(lit.to_tuple().is_err());
        assert!(Literal::scalar(7i32).reshape(&[2]).is_err());
    }

    #[test]
    fn client_reports_runtime_unavailable() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(err.to_string().contains("not linked"));
    }
}
