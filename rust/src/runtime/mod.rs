//! PJRT artifact runtime: loads the HLO-text artifacts that
//! `python/compile/aot.py` produced and executes them from the request
//! path, Python-free.
//!
//! Pattern (from /opt/xla-example/load_hlo):
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. HLO *text* is the interchange
//! format; serialized protos from jax ≥ 0.5 are rejected by
//! xla_extension 0.5.1 (64-bit instruction ids).

mod artifact;
mod server;
mod tensor;
pub(crate) mod xla_stub;

pub use artifact::{ArtifactSpec, IoSpec, Manifest};
pub use server::{shared_runtime, ObsServer, XlaRuntime};
pub use tensor::{Tensor, TensorData};

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        let ok = crate::artifacts_dir().join("manifest.json").is_file();
        if !ok {
            eprintln!("skipped: run `make artifacts` to enable artifact-gated tests");
        }
        ok
    }

    #[test]
    fn feature_artifact_executes() {
        if !have_artifacts() {
            return;
        }
        let rt = shared_runtime().unwrap();
        let x = Tensor::from_f32(vec![0.5; 64 * 64], &[1, 64, 64]).unwrap();
        let out = rt.execute("feature_b1", vec![x]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape, vec![1, 8, 8, 4]);
        // Constant image -> zero gradients everywhere.
        assert!(out[0].as_f32().unwrap().iter().all(|v| v.abs() < 1e-5));
    }

    #[test]
    fn icp_artifact_identity_clouds() {
        if !have_artifacts() {
            return;
        }
        let rt = shared_runtime().unwrap();
        let mut rng = crate::util::Rng::new(5);
        let pts: Vec<f32> = (0..1024 * 3).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let src = Tensor::from_f32(pts.clone(), &[1024, 3]).unwrap();
        let dst = Tensor::from_f32(pts, &[1024, 3]).unwrap();
        let out = rt.execute("icp_step_1024", vec![src, dst]).unwrap();
        assert_eq!(out.len(), 4);
        let err = out[3].scalar_value().unwrap();
        assert!(err.abs() < 1e-6, "identical clouds, err={err}");
    }

    #[test]
    fn input_validation_rejects_bad_shape() {
        if !have_artifacts() {
            return;
        }
        let rt = shared_runtime().unwrap();
        let bad = Tensor::zeros(&[2, 2]);
        assert!(rt.execute("feature_b1", vec![bad]).is_err());
    }

    #[test]
    fn round_robin_covers_devices() {
        if !have_artifacts() {
            return;
        }
        let rt = shared_runtime().unwrap();
        assert!(rt.num_devices() >= 1);
        // execute_on out of range errors cleanly
        let x = Tensor::from_f32(vec![0.0; 64 * 64], &[1, 64, 64]).unwrap();
        assert!(rt.execute_on(usize::MAX, "feature_b1", vec![x]).is_err());
    }
}
