//! Device servers: dedicated threads owning a PJRT client + compiled
//! executables, fed through channels.
//!
//! `xla::PjRtClient` is `Rc`-backed and must not cross threads, so each
//! accelerator ("GPU-class device" in the paper's terms) is a thread
//! that compiles HLO-text artifacts once and then serves execute
//! requests from its queue — the same shape as a real accelerator's
//! submission queue. [`XlaRuntime`] is the cheap, clonable, `Send+Sync`
//! handle the rest of the platform uses.

use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::artifact::Manifest;
use super::tensor::Tensor;
use super::xla_stub as xla;
use crate::metrics::MetricsRegistry;
use crate::obs::Observability;

enum Request {
    Execute {
        name: String,
        inputs: Vec<Tensor>,
        resp: mpsc::Sender<Result<Vec<Tensor>>>,
    },
    Preload {
        names: Vec<String>,
        resp: mpsc::Sender<Result<()>>,
    },
    Shutdown,
}

/// One device-server thread.
struct DeviceServer {
    tx: mpsc::Sender<Request>,
    handle: Option<JoinHandle<()>>,
}

impl DeviceServer {
    fn spawn(device_id: usize, manifest: Arc<Manifest>, metrics: MetricsRegistry) -> Self {
        let (tx, rx) = mpsc::channel::<Request>();
        let handle = std::thread::Builder::new()
            .name(format!("xla-device-{device_id}"))
            .spawn(move || device_loop(rx, manifest, metrics))
            .expect("spawn device server");
        Self { tx, handle: Some(handle) }
    }
}

impl Drop for DeviceServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn device_loop(rx: mpsc::Receiver<Request>, manifest: Arc<Manifest>, metrics: MetricsRegistry) {
    // The PJRT client and every compiled executable live and die on this
    // thread; only `Tensor`s cross the channel.
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Fail every request with a clear error.
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Execute { resp, .. } => {
                        let _ = resp.send(Err(anyhow!("PJRT client init failed: {e:?}")));
                    }
                    Request::Preload { resp, .. } => {
                        let _ = resp.send(Err(anyhow!("PJRT client init failed: {e:?}")));
                    }
                    Request::Shutdown => break,
                }
            }
            return;
        }
    };
    let mut exes: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

    let compile = |name: &str,
                   exes: &mut HashMap<String, xla::PjRtLoadedExecutable>|
     -> Result<()> {
        if exes.contains_key(name) {
            return Ok(());
        }
        let path = manifest.hlo_path(name)?;
        let start = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .map_err(|e| anyhow!("parsing HLO text for {name}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        metrics
            .histogram(&format!("runtime.compile.{name}"))
            .record(start.elapsed());
        exes.insert(name.to_string(), exe);
        Ok(())
    };

    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Preload { names, resp } => {
                let mut out = Ok(());
                for n in &names {
                    if let Err(e) = compile(n, &mut exes) {
                        out = Err(e);
                        break;
                    }
                }
                let _ = resp.send(out);
            }
            Request::Execute { name, inputs, resp } => {
                let result = (|| -> Result<Vec<Tensor>> {
                    let spec = manifest.get(&name)?;
                    spec.check_inputs(&inputs)?;
                    compile(&name, &mut exes)?;
                    let exe = exes.get(&name).unwrap();
                    let lits: Vec<xla::Literal> = inputs
                        .iter()
                        .map(|t| t.to_literal())
                        .collect::<Result<_>>()?;
                    let start = Instant::now();
                    let bufs = exe
                        .execute::<xla::Literal>(&lits)
                        .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
                    let out_lit = bufs[0][0]
                        .to_literal_sync()
                        .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
                    metrics
                        .histogram(&format!("runtime.exec.{name}"))
                        .record(start.elapsed());
                    metrics.counter(&format!("runtime.execs.{name}")).inc();
                    // Artifacts are lowered with return_tuple=True.
                    let parts = out_lit
                        .to_tuple()
                        .map_err(|e| anyhow!("untupling {name} result: {e:?}"))?;
                    if parts.len() != spec.outputs.len() {
                        return Err(anyhow!(
                            "{name}: {} outputs, manifest says {}",
                            parts.len(),
                            spec.outputs.len()
                        ));
                    }
                    parts.iter().map(Tensor::from_literal).collect()
                })();
                let _ = resp.send(result);
            }
        }
    }
}

/// Handle to a pool of device-server threads (round-robin dispatch).
///
/// Clone freely; all clones share the same devices. In the platform's
/// terms each underlying server is one GPU-class accelerator; the
/// resource manager hands out device indices and services pin their
/// work with [`XlaRuntime::execute_on`].
#[derive(Clone)]
pub struct XlaRuntime {
    inner: Arc<RuntimeInner>,
}

struct RuntimeInner {
    manifest: Arc<Manifest>,
    devices: Vec<DeviceServer>,
    next: AtomicUsize,
    metrics: MetricsRegistry,
}

impl XlaRuntime {
    /// Load the manifest from `dir` and spin up `num_devices` servers.
    pub fn new(
        dir: impl AsRef<std::path::Path>,
        num_devices: usize,
        metrics: MetricsRegistry,
    ) -> Result<Self> {
        let manifest = Arc::new(Manifest::load(dir)?);
        let devices = (0..num_devices.max(1))
            .map(|i| DeviceServer::spawn(i, manifest.clone(), metrics.clone()))
            .collect();
        Ok(Self {
            inner: Arc::new(RuntimeInner { manifest, devices, next: AtomicUsize::new(0), metrics }),
        })
    }

    /// Convenience: default artifacts dir, one device, fresh metrics.
    pub fn single() -> Result<Self> {
        Self::new(crate::artifacts_dir(), 1, MetricsRegistry::new())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.inner.manifest
    }

    pub fn metrics(&self) -> &MetricsRegistry {
        &self.inner.metrics
    }

    pub fn num_devices(&self) -> usize {
        self.inner.devices.len()
    }

    /// Execute an artifact on the least-recently-used device.
    pub fn execute(&self, name: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let d = self.inner.next.fetch_add(1, Ordering::Relaxed) % self.inner.devices.len();
        self.execute_on(d, name, inputs)
    }

    /// Execute an artifact on a specific device queue.
    pub fn execute_on(
        &self,
        device: usize,
        name: &str,
        inputs: Vec<Tensor>,
    ) -> Result<Vec<Tensor>> {
        let dev = self
            .inner
            .devices
            .get(device)
            .ok_or_else(|| anyhow!("device {device} out of range"))?;
        let (tx, rx) = mpsc::channel();
        dev.tx
            .send(Request::Execute { name: name.to_string(), inputs, resp: tx })
            .map_err(|_| anyhow!("device {device} is gone"))?;
        rx.recv().map_err(|_| anyhow!("device {device} dropped the request"))?
    }

    /// Compile the named artifacts on every device up front.
    pub fn preload(&self, names: &[&str]) -> Result<()> {
        for dev in &self.inner.devices {
            let (tx, rx) = mpsc::channel();
            dev.tx
                .send(Request::Preload {
                    names: names.iter().map(|s| s.to_string()).collect(),
                    resp: tx,
                })
                .map_err(|_| anyhow!("device gone during preload"))?;
            rx.recv().map_err(|_| anyhow!("device dropped preload"))??;
        }
        Ok(())
    }
}

/// Minimal HTTP scrape endpoint over the telemetry plane: `/metrics`
/// serves the registry in Prometheus text format, `/healthz` the
/// watchdog rollup as JSON. One nonblocking-accept thread, plain
/// `std::net` — no HTTP framework, requests are one-line GETs.
pub struct ObsServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl ObsServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and
    /// serve `obs` until dropped.
    pub fn serve(addr: &str, obs: Arc<Observability>) -> Result<Self> {
        let listener = std::net::TcpListener::bind(addr)
            .with_context(|| format!("binding obs server to {addr}"))?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("obs-http".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((mut conn, _)) => {
                            let _ = serve_one(&mut conn, &obs);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(10));
                        }
                        Err(_) => break,
                    }
                }
            })
            .expect("spawn obs-http thread");
        Ok(Self { addr: local, stop, handle: Some(handle) })
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ObsServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_one(conn: &mut std::net::TcpStream, obs: &Observability) -> std::io::Result<()> {
    conn.set_read_timeout(Some(Duration::from_millis(500)))?;
    let mut buf = [0u8; 1024];
    let n = conn.read(&mut buf)?;
    let req = String::from_utf8_lossy(&buf[..n]);
    let path = req.split_whitespace().nth(1).unwrap_or("/");
    let (status, ctype, body) = if path.starts_with("/metrics") {
        ("200 OK", "text/plain; version=0.0.4", obs.prometheus_text())
    } else if path.starts_with("/healthz") {
        ("200 OK", "application/json", obs.health_json().to_string_pretty())
    } else {
        ("404 Not Found", "text/plain", "try /metrics or /healthz\n".to_string())
    };
    write!(
        conn,
        "HTTP/1.0 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    conn.flush()
}

/// Global shared runtime for tests/benches: PJRT clients are expensive, so
/// everything in-process shares one pool.
static SHARED: Mutex<Option<XlaRuntime>> = Mutex::new(None);

/// Get (or lazily create) the process-wide runtime with 2 devices.
pub fn shared_runtime() -> Result<XlaRuntime> {
    let mut guard = SHARED.lock().unwrap();
    if let Some(rt) = guard.as_ref() {
        return Ok(rt.clone());
    }
    let rt = XlaRuntime::new(crate::artifacts_dir(), 2, MetricsRegistry::new())?;
    *guard = Some(rt.clone());
    Ok(rt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::ObsConfig;

    #[test]
    fn obs_server_serves_metrics_and_healthz() {
        let m = MetricsRegistry::new();
        m.counter("runtime.test.hits").add(2);
        let obs = Observability::start(m, ObsConfig::default());
        let mut srv = ObsServer::serve("127.0.0.1:0", obs.clone()).unwrap();
        let fetch = |path: &str| {
            let mut s = std::net::TcpStream::connect(srv.addr()).unwrap();
            write!(s, "GET {path} HTTP/1.0\r\n\r\n").unwrap();
            let mut out = String::new();
            s.read_to_string(&mut out).unwrap();
            out
        };
        let metrics = fetch("/metrics");
        assert!(metrics.contains("200 OK"), "{metrics}");
        assert!(metrics.contains("runtime_test_hits 2"), "{metrics}");
        let health = fetch("/healthz");
        assert!(health.contains("\"status\""), "{health}");
        assert!(fetch("/nope").contains("404"));
        srv.stop();
        obs.stop();
    }
}
