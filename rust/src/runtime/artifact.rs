//! Artifact manifest: what `python -m compile.aot` produced.
//!
//! `artifacts/manifest.json` is the contract between the build-time
//! Python layer and the Rust request path: artifact names, HLO files,
//! and the exact input/output tensor signatures each executable expects.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use super::tensor::Tensor;
use crate::util::json::Json;

/// One input or output slot of an artifact.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            name: j.req("name")?.as_str()?.to_string(),
            shape: j
                .req("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<_>>()?,
            dtype: j.req("dtype")?.as_str()?.to_string(),
        })
    }
}

/// One AOT-compiled entry point.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactSpec {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(Self {
            name: j.req("name")?.as_str()?.to_string(),
            file: j.req("file")?.as_str()?.to_string(),
            inputs: j
                .req("inputs")?
                .as_arr()?
                .iter()
                .map(IoSpec::from_json)
                .collect::<Result<_>>()?,
            outputs: j
                .req("outputs")?
                .as_arr()?
                .iter()
                .map(IoSpec::from_json)
                .collect::<Result<_>>()?,
        })
    }
}

impl ArtifactSpec {
    /// Validate a set of host tensors against the input signature.
    pub fn check_inputs(&self, inputs: &[Tensor]) -> Result<()> {
        if inputs.len() != self.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.inputs.len(),
                inputs.len()
            );
        }
        for (t, spec) in inputs.iter().zip(&self.inputs) {
            if t.shape != spec.shape {
                bail!(
                    "{}: input '{}' shape {:?} != expected {:?}",
                    self.name,
                    spec.name,
                    t.shape,
                    spec.shape
                );
            }
            if t.dtype_tag() != spec.dtype {
                bail!(
                    "{}: input '{}' dtype {} != expected {}",
                    self.name,
                    spec.name,
                    t.dtype_tag(),
                    spec.dtype
                );
            }
        }
        Ok(())
    }
}

/// Parsed manifest plus its directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub param_order: Vec<String>,
    by_name: HashMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let format = j.req("format")?.as_str()?;
        if format != "hlo-text/v1" {
            bail!("unsupported artifact format {format:?}");
        }
        let by_name: HashMap<String, ArtifactSpec> = j
            .req("artifacts")?
            .as_arr()?
            .iter()
            .map(|a| ArtifactSpec::from_json(a).map(|s| (s.name.clone(), s)))
            .collect::<Result<_>>()?;
        let param_order = match j.get("param_order") {
            Some(p) => p
                .as_arr()?
                .iter()
                .map(|s| s.as_str().map(str::to_string))
                .collect::<Result<_>>()?,
            None => Vec::new(),
        };
        Ok(Self { dir, param_order, by_name })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.by_name
            .get(name)
            .with_context(|| format!("unknown artifact '{name}' (have: {:?})", self.names()))
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.by_name.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.get(name)?.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArtifactSpec {
        ArtifactSpec {
            name: "t".into(),
            file: "t.hlo.txt".into(),
            inputs: vec![
                IoSpec { name: "x".into(), shape: vec![2, 3], dtype: "f32".into() },
                IoSpec { name: "y".into(), shape: vec![2], dtype: "s32".into() },
            ],
            outputs: vec![IoSpec { name: "o".into(), shape: vec![], dtype: "f32".into() }],
        }
    }

    #[test]
    fn check_inputs_accepts_matching() {
        let s = spec();
        let ins = vec![
            Tensor::from_f32(vec![0.0; 6], &[2, 3]).unwrap(),
            Tensor::from_i32(vec![1, 2], &[2]).unwrap(),
        ];
        assert!(s.check_inputs(&ins).is_ok());
    }

    #[test]
    fn check_inputs_rejects_shape_dtype_arity() {
        let s = spec();
        // arity
        assert!(s.check_inputs(&[Tensor::zeros(&[2, 3])]).is_err());
        // shape
        let bad = vec![Tensor::zeros(&[3, 2]), Tensor::from_i32(vec![1, 2], &[2]).unwrap()];
        assert!(s.check_inputs(&bad).is_err());
        // dtype
        let bad = vec![Tensor::zeros(&[2, 3]), Tensor::zeros(&[2])];
        assert!(s.check_inputs(&bad).is_err());
    }

    #[test]
    fn manifest_loads_built_artifacts_if_present() {
        let dir = crate::artifacts_dir();
        if !dir.join("manifest.json").is_file() {
            return; // artifacts not built in this environment
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.get("cnn_train_b16").is_ok());
        assert_eq!(m.get("cnn_train_b16").unwrap().inputs.len(), 8);
        assert!(m.hlo_path("icp_step_1024").unwrap().is_file());
        assert_eq!(m.param_order.len(), 6);
    }
}
