//! Fleet-ingestion end-to-end tests: gateway -> partitioned log ->
//! compaction into tiered storage (with lineage) -> scenario mining ->
//! a campaign the scenario engine executes unmodified.

use adcloud::ingest::{
    self, CompactorConfig, FleetConfig, GatewayConfig, IngestGateway, LogConfig, MinerConfig,
    PartitionedLog,
};
use adcloud::metrics::MetricsRegistry;
use adcloud::platform::Platform;
use adcloud::scenario;

/// Run the whole pipeline once; returns (platform, fleet, compaction, mined).
fn run_pipeline(
    tag: &str,
    seed: u64,
) -> (Platform, ingest::FleetReport, ingest::CompactionReport, ingest::MineReport) {
    let p = Platform::local().unwrap();
    let log = PartitionedLog::temp(
        tag,
        LogConfig {
            partitions: 4,
            segment_bytes: 32 << 10,
            retention_bytes: 32 << 20,
            ..Default::default()
        },
    )
    .unwrap();
    let gw = IngestGateway::new(log.clone(), GatewayConfig::default(), MetricsRegistry::new());
    let mut fleet_cfg = FleetConfig::new(8, 400, seed);
    fleet_cfg.corrupt_rate = 0.02;
    let fleet = ingest::simulate_fleet(&gw, &fleet_cfg).unwrap();
    let compaction = ingest::compact(
        &log,
        p.ctx.store(),
        &p.resources,
        &CompactorConfig::new(format!("e2e-{tag}"), 2),
    )
    .unwrap();
    // Every accepted upload must be drained.
    for part in 0..log.partitions() {
        assert_eq!(log.lag(part), 0, "partition {part} not drained");
    }
    let mined = ingest::mine(
        &p.ctx,
        &p.resources,
        p.ctx.store(),
        &compaction.blocks,
        &MinerConfig::default(),
    )
    .unwrap();
    assert_eq!(p.resources.live_containers(), 0, "compaction + mining grants returned");
    (p, fleet, compaction, mined)
}

#[test]
fn fleet_to_campaign_end_to_end() {
    let (p, fleet, compaction, mined) = run_pipeline("e2e", 42);
    assert!(fleet.accepted > 0);
    assert!(fleet.dead_lettered > 0, "2% corruption must dead-letter some uploads");
    assert_eq!(compaction.records, fleet.accepted, "compaction must drain exactly what landed");
    assert!(!compaction.blocks.is_empty());
    assert!(!mined.families().is_empty(), "mining must emit at least one scenario family");
    assert!(!mined.specs.is_empty());

    // The mined specs run through the campaign engine UNMODIFIED.
    let specs: Vec<_> = mined.specs.iter().take(6).cloned().collect();
    let ccfg = scenario::CampaignConfig::new("e2e-mined", 2);
    let report = scenario::run_campaign(&p.ctx, &p.resources, &specs, &ccfg).unwrap();
    assert_eq!(report.scenarios, specs.len());
    assert_eq!(p.resources.live_containers(), 0, "all grants returned");
}

#[test]
fn pipeline_is_deterministic_across_runs() {
    let (_, fleet_a, _, mined_a) = run_pipeline("det-a", 7);
    let (_, fleet_b, _, mined_b) = run_pipeline("det-b", 7);
    assert_eq!(fleet_a.accepted, fleet_b.accepted);
    assert_eq!(fleet_a.dead_lettered, fleet_b.dead_lettered);
    assert_eq!(mined_a.events, mined_b.events);
    assert_eq!(
        scenario::campaign_digest(&mined_a.specs),
        scenario::campaign_digest(&mined_b.specs),
        "same fleet seed must mine byte-identical spec sets"
    );
}

#[test]
fn compacted_blocks_survive_tier_loss_via_lineage() {
    let (p, _, compaction, _) = run_pipeline("lineage", 3);
    let store = p.ctx.store();
    let block = &compaction.blocks[0];
    let original = store.get(&block.key).unwrap().as_ref().clone();
    // Lose the block from every tier AND the durable under-store; the
    // only way back is the lineage rule the compactor registered.
    store.flush();
    store.delete(&block.key).unwrap();
    let recovered = store.get(&block.key).unwrap();
    assert_eq!(*recovered, original, "lineage must rebuild the exact block bytes");
}

#[test]
fn e14_quick_reports_all_partition_counts() {
    let table = adcloud::platform::experiments::run_experiment("e14", true).unwrap();
    assert_eq!(table.rows.len(), 4);
    let parts: Vec<&str> = table.rows.iter().map(|r| r[0].as_str()).collect();
    assert_eq!(parts, vec!["1", "2", "4", "8"]);
}
