//! Property-based tests (hand-rolled harness — proptest is not in the
//! offline vendored crate set). Each property runs against many
//! randomized cases from the deterministic in-tree RNG; failures print
//! the case seed for reproduction.

use adcloud::config::{PlatformConfig, StorageConfig, TierConfig};
use adcloud::dce::{decode_stream, encode_records, DceContext};
use adcloud::pointcloud::{kabsch_rotation, m_apply, m_det, m_mul, m_transpose, KdTree};
use adcloud::storage::{EvictionPolicy, TieredStore};
use adcloud::util::json::Json;
use adcloud::util::Rng;
use std::collections::HashMap;

/// Run `f` over `cases` seeds, reporting the failing seed.
fn forall(name: &str, cases: u64, f: impl Fn(&mut Rng)) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xABCD_0000 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property '{name}' failed at seed {seed}: {e:?}");
        }
    }
}

fn random_records(rng: &mut Rng) -> Vec<Vec<u8>> {
    let n = rng.below(40) as usize;
    (0..n)
        .map(|_| {
            let len = rng.below(4000) as usize;
            (0..len).map(|_| rng.below(256) as u8).collect()
        })
        .collect()
}

#[test]
fn prop_binpipe_roundtrip() {
    forall("binpipe roundtrip", 50, |rng| {
        let records = random_records(rng);
        let decoded = decode_stream(&encode_records(&records)).unwrap();
        assert_eq!(decoded, records);
    });
}

#[test]
fn prop_binpipe_rejects_truncation() {
    forall("binpipe truncation", 30, |rng| {
        let mut records = random_records(rng);
        records.push(vec![1, 2, 3]); // ensure non-empty stream
        let stream = encode_records(&records);
        let cut = 1 + rng.below(stream.len() as u64 - 1) as usize;
        assert!(
            decode_stream(&stream[..cut]).is_err(),
            "accepted a stream truncated to {cut}/{} bytes",
            stream.len()
        );
    });
}

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.next_f64() < 0.5),
        2 => Json::Num((rng.next_f64() * 2e6).round() / 2.0 - 5e5),
        3 => {
            let len = rng.below(12) as usize;
            Json::Str(
                (0..len)
                    .map(|_| {
                        // printable ascii + some escapes + unicode
                        match rng.below(20) {
                            0 => '"',
                            1 => '\\',
                            2 => '\n',
                            3 => 'é',
                            4 => '😀',
                            _ => (b' ' + rng.below(94) as u8) as char,
                        }
                    })
                    .collect(),
            )
        }
        4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.below(5))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    forall("json roundtrip", 100, |rng| {
        let v = random_json(rng, 3);
        let compact = Json::parse(&v.to_string()).unwrap();
        let pretty = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(compact, v);
        assert_eq!(pretty, v);
    });
}

#[test]
fn prop_rdd_matches_vec_semantics() {
    let ctx = DceContext::local().unwrap();
    forall("rdd vs Vec", 20, |rng| {
        let n = 1 + rng.below(500) as usize;
        let parts = 1 + rng.below(9) as usize;
        let data: Vec<u64> = (0..n).map(|_| rng.below(1000)).collect();
        let rdd = ctx.parallelize(data.clone(), parts);
        // map+filter+count
        let got = rdd.map(|x| x * 3).filter(|x| x % 2 == 0).count().unwrap();
        let want = data.iter().map(|x| x * 3).filter(|x| x % 2 == 0).count();
        assert_eq!(got, want);
        // reduce (associative op)
        assert_eq!(
            rdd.reduce(|a, b| a.wrapping_add(b)).unwrap(),
            data.iter().copied().reduce(|a, b| a.wrapping_add(b))
        );
    });
}

#[test]
fn prop_reduce_by_key_matches_hashmap() {
    let ctx = DceContext::local().unwrap();
    forall("reduce_by_key vs HashMap", 20, |rng| {
        let n = rng.below(800) as usize;
        let parts = 1 + rng.below(6) as usize;
        let reducers = 1 + rng.below(6) as usize;
        let pairs: Vec<(u32, u64)> =
            (0..n).map(|_| (rng.below(20) as u32, rng.below(100))).collect();
        let mut want: HashMap<u32, u64> = HashMap::new();
        for (k, v) in &pairs {
            *want.entry(*k).or_default() += v;
        }
        let got: HashMap<u32, u64> = ctx
            .parallelize(pairs, parts)
            .reduce_by_key(|a, b| a + b, reducers)
            .collect()
            .unwrap()
            .into_iter()
            .collect();
        assert_eq!(got, want);
    });
}

#[test]
fn prop_tiered_store_never_loses_acked_blocks() {
    forall("tiered store durability", 15, |rng| {
        // Tiny tiers force constant eviction cascades.
        let cfg = StorageConfig {
            mem: TierConfig { capacity_bytes: 2000, bandwidth_bps: 1e12, latency_us: 0 },
            ssd: TierConfig { capacity_bytes: 4000, bandwidth_bps: 1e12, latency_us: 0 },
            hdd: TierConfig { capacity_bytes: 8000, bandwidth_bps: 1e12, latency_us: 0 },
            dfs: TierConfig { capacity_bytes: u64::MAX, bandwidth_bps: 1e12, latency_us: 0 },
            ..StorageConfig::default()
        };
        let store = TieredStore::test_store(&cfg);
        let mut model: HashMap<String, Vec<u8>> = HashMap::new();
        for op in 0..120 {
            let key = format!("k{}", rng.below(30));
            match rng.below(10) {
                0..=5 => {
                    let len = 1 + rng.below(900) as usize;
                    let val = vec![(op % 251) as u8; len];
                    store.put(&key, val.clone()).unwrap();
                    model.insert(key, val);
                }
                6..=8 => {
                    if let Some(want) = model.get(&key) {
                        // Any previously acked block must come back intact,
                        // possibly via under-store after a full cascade.
                        store.flush();
                        let got = store.get(&key).unwrap();
                        assert_eq!(got.as_ref(), want, "block {key} corrupted");
                    }
                }
                _ => {
                    store.delete(&key).unwrap();
                    model.remove(&key);
                }
            }
        }
        // Final audit of everything the model says should exist.
        store.flush();
        for (key, want) in &model {
            let got = store.get(key).unwrap();
            assert_eq!(got.as_ref(), want, "final audit lost {key}");
        }
    });
}

#[test]
fn prop_eviction_policies_only_return_candidates() {
    forall("eviction candidates", 30, |rng| {
        use adcloud::storage::BlockMeta;
        let n = 1 + rng.below(20) as usize;
        let metas: Vec<(String, BlockMeta)> = (0..n)
            .map(|i| {
                (
                    format!("b{i}"),
                    BlockMeta {
                        size: 1 + rng.below(100),
                        tier: 0,
                        pinned: false,
                        last_seq: rng.below(1000),
                        hits: rng.below(50),
                        crf: rng.next_f64() * 10.0,
                    },
                )
            })
            .collect();
        let map: HashMap<String, BlockMeta> = metas.into_iter().collect();
        for policy in [EvictionPolicy::Lru, EvictionPolicy::Lrfu { lambda: 0.3 }] {
            let victim = policy.choose(map.iter(), 1000).unwrap();
            assert!(map.contains_key(&victim));
        }
    });
}

#[test]
fn prop_simclock_more_cores_never_slower() {
    use adcloud::dce::{simclock, SimCluster, SimJob, SimTask};
    use std::time::Duration;
    forall("simclock monotone in cores", 20, |rng| {
        let tasks: Vec<SimTask> = (0..50 + rng.below(200) as usize)
            .map(|_| SimTask::compute_only(Duration::from_micros(100 + rng.below(10_000))))
            .collect();
        let job = SimJob::single_stage("p", tasks);
        let mk = |cores: usize| {
            let c = SimCluster {
                nodes: 1,
                cores_per_node: cores,
                net_bps: 1e9,
                disk_bps: 1e9,
                sched_overhead: Duration::ZERO,
                straggler_cv: 0.0,
                seed: 1,
            };
            simclock::simulate(&c, &job).makespan
        };
        let c1 = 1 + rng.below(8) as usize;
        let c2 = c1 * 2;
        assert!(mk(c2) <= mk(c1), "more cores made it slower");
    });
}

#[test]
fn prop_kabsch_always_proper_rotation() {
    forall("kabsch proper rotation", 60, |rng| {
        let mut h = [[0f32; 3]; 3];
        for row in h.iter_mut() {
            for x in row.iter_mut() {
                *x = rng.normal_f32(0.0, 3.0);
            }
        }
        let r = kabsch_rotation(&h);
        let rtr = m_mul(&m_transpose(&r), &r);
        for i in 0..3 {
            for j in 0..3 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((rtr[i][j] - want).abs() < 2e-3, "not orthonormal");
            }
        }
        assert!((m_det(&r) - 1.0).abs() < 2e-3, "det {}", m_det(&r));
    });
}

#[test]
fn prop_kdtree_matches_bruteforce() {
    forall("kdtree vs brute force", 25, |rng| {
        let n = 1 + rng.below(300) as usize;
        let pts: Vec<f32> = (0..n * 3).map(|_| rng.normal_f32(0.0, 10.0)).collect();
        let tree = KdTree::build(&pts);
        for _ in 0..20 {
            let q = [
                rng.normal_f32(0.0, 10.0),
                rng.normal_f32(0.0, 10.0),
                rng.normal_f32(0.0, 10.0),
            ];
            let (_, d_tree) = tree.nearest(q).unwrap();
            let d_brute = pts
                .chunks_exact(3)
                .map(|p| {
                    (q[0] - p[0]).powi(2) + (q[1] - p[1]).powi(2) + (q[2] - p[2]).powi(2)
                })
                .fold(f32::INFINITY, f32::min);
            assert!((d_tree - d_brute).abs() < 1e-3, "{d_tree} vs {d_brute}");
        }
    });
}

#[test]
fn prop_se3_apply_cloud_invertible() {
    use adcloud::pointcloud::{rot_z, Se3};
    forall("se3 invertible on clouds", 30, |rng| {
        let n = 1 + rng.below(100) as usize;
        let pts: Vec<f32> = (0..n * 3).map(|_| rng.normal_f32(0.0, 5.0)).collect();
        let tf = Se3::new(
            rot_z(rng.normal_f32(0.0, 1.0)),
            [rng.normal_f32(0.0, 3.0), rng.normal_f32(0.0, 3.0), rng.normal_f32(0.0, 3.0)],
        );
        let round = tf.inverse().apply_cloud(&tf.apply_cloud(&pts));
        for (a, b) in pts.iter().zip(round.iter()) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
        // Rotations preserve pairwise distances.
        if n >= 2 {
            let d0 = ((pts[0] - pts[3]).powi(2)
                + (pts[1] - pts[4]).powi(2)
                + (pts[2] - pts[5]).powi(2))
            .sqrt();
            let moved = tf.apply_cloud(&pts[..6]);
            let d1 = ((moved[0] - moved[3]).powi(2)
                + (moved[1] - moved[4]).powi(2)
                + (moved[2] - moved[5]).powi(2))
            .sqrt();
            assert!((d0 - d1).abs() < 1e-3);
        }
    });
}

#[test]
fn prop_config_json_roundtrip() {
    forall("config roundtrip", 20, |rng| {
        let mut cfg = PlatformConfig::test();
        cfg.cluster.nodes = 1 + rng.below(32) as usize;
        cfg.cluster.cores_per_node = 1 + rng.below(64) as usize;
        cfg.seed = rng.next_u64() >> 12; // keep within f64-exact ints
        cfg.storage.mem.capacity_bytes = rng.below(1 << 40);
        let json = cfg.to_json().to_string();
        let back = PlatformConfig::from_json(&Json::parse(&json).unwrap()).unwrap();
        assert_eq!(back.cluster, cfg.cluster);
        assert_eq!(back.seed, cfg.seed);
        assert_eq!(back.storage.mem, cfg.storage.mem);
    });
}

#[test]
fn prop_resample_preserves_membership() {
    use adcloud::services::mapgen::resample;
    forall("icp resample membership", 30, |rng| {
        let n = 1 + rng.below(500) as usize;
        let pts: Vec<f32> = (0..n * 3).map(|_| rng.normal_f32(0.0, 5.0)).collect();
        let target = [16usize, 128, 1024][rng.below(3) as usize];
        let out = resample(&pts, target, rng.next_u64());
        assert_eq!(out.len(), target * 3);
        // Every output point must be one of the input points.
        let set: std::collections::HashSet<[u32; 3]> = pts
            .chunks_exact(3)
            .map(|p| [p[0].to_bits(), p[1].to_bits(), p[2].to_bits()])
            .collect();
        for p in out.chunks_exact(3) {
            assert!(set.contains(&[p[0].to_bits(), p[1].to_bits(), p[2].to_bits()]));
        }
    });
}
