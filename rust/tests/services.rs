//! End-to-end service tests: the three paper services exercised at
//! small scale through the public API.

use adcloud::platform::Platform;
use adcloud::resource::DeviceKind;
use adcloud::services::{mapgen, simulation, sql, training};
use adcloud::util::Rng;

fn have_artifacts() -> bool {
    let ok = adcloud::artifacts_dir().join("manifest.json").is_file();
    if !ok {
        eprintln!("skipped: run `make artifacts` to enable artifact-gated tests");
    }
    ok
}

#[test]
fn simulation_service_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let p = Platform::local().unwrap();
    let dir = std::env::temp_dir().join(format!("adsvc-sim-{}", std::process::id()));
    let bags = simulation::record_drive(&dir, 6, 8, 123).unwrap();
    let report = simulation::replay(&p.ctx, &p.dispatcher, &bags, DeviceKind::Gpu).unwrap();
    assert_eq!(report.frames, 48);
    assert!(report.accuracy > 0.55, "accuracy {}", report.accuracy);
    // The algorithm qualifies only if it beats the qualification bar —
    // this IS the paper's "only after passing simulation tests" gate.
    let qualifies = report.accuracy >= 0.6;
    assert!(qualifies, "detector failed qualification at {:.2}", report.accuracy);
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn training_service_end_to_end_loss_decreases() {
    if !have_artifacts() {
        return;
    }
    let p = Platform::local().unwrap();
    let data = training::gen_dataset(128, 5);
    let shards = training::shard(data, 2);
    let trainer = training::DistTrainer::new(p.dispatcher.clone(), DeviceKind::Gpu, shards);
    let ps = training::ParamServer::tiered(p.ctx.store().clone(), "svc");
    let init = adcloud::hetero::cpu_impls::init_params(&mut Rng::new(1));
    let report = trainer.train(&ps, init, 10, 0.05).unwrap();
    assert!(report.last_loss() < report.first_loss());
    // Parameters are durable through the store.
    p.ctx.store().flush();
    assert!(ps.pull(10).is_ok());
}

#[test]
fn mapgen_service_end_to_end() {
    if !have_artifacts() {
        return;
    }
    let p = Platform::local().unwrap();
    let world = mapgen::gen_world(321);
    let log = mapgen::gen_drive(&world, 80, 321);
    let report = mapgen::run_fused(
        &p.dispatcher,
        &p.resources,
        &log,
        &mapgen::SlamConfig { icp_every: 20, ..Default::default() },
        &adcloud::platform::JobOpts::new("mapgen-fused"),
        0.1,
    )
    .unwrap();
    assert_eq!(p.resources.live_containers(), 0, "mapgen grant returned");
    assert!(report.slam_err_m < 2.5, "slam err {}", report.slam_err_m);
    assert!(report.occupied_cells > 500);
    // Map answers the paper's three layer queries: grid, lane, signs.
    let pose = log.poses_gt[40];
    assert!(report.map.on_lane(pose.t[0], pose.t[1]));
    assert!(report.map.grid.total_hits() > 0);
    let _ = report.map.nearest_sign(pose.t[0], pose.t[1]);
}

#[test]
fn sql_service_consistency_across_engines() {
    let p = Platform::local().unwrap();
    let data = sql::generate_telemetry(3000, 30, 9);
    let rdd = p.ctx.parallelize(data.clone(), 6);
    let dce_rows = sql::q1_dce(&rdd, 4).unwrap();
    let dfs = p.ctx.dfs().clone();
    let engine =
        adcloud::mapreduce::MapReduceEngine::new(4, dfs, adcloud::metrics::MetricsRegistry::new());
    let input = engine.write_file(data, 6).unwrap();
    let mr_rows = sql::q1_mr(&engine, &input, 4).unwrap();
    assert_eq!(dce_rows.len(), mr_rows.len());
    for (a, b) in dce_rows.iter().zip(mr_rows.iter()) {
        assert_eq!(a.0, b.0);
        assert!((a.1 - b.1).abs() < 1e-9);
    }
}

#[test]
fn piped_and_inprocess_replay_agree() {
    if !have_artifacts() {
        return;
    }
    // The piped mode needs the adcloud binary; skip when absent.
    let exe = std::env::current_exe().unwrap();
    let bin = exe
        .parent()
        .and_then(|p| p.parent())
        .map(|p| p.join("adcloud"))
        .filter(|p| p.is_file());
    let Some(bin) = bin else { return };
    let p = Platform::local().unwrap();
    let dir = std::env::temp_dir().join(format!("adsvc-pipe-{}", std::process::id()));
    let bags = simulation::record_drive(&dir, 3, 8, 55).unwrap();
    let inproc = simulation::replay(&p.ctx, &p.dispatcher, &bags, DeviceKind::Cpu).unwrap();
    let piped = simulation::replay_piped(
        &p.ctx,
        &bags,
        vec![bin.to_string_lossy().into_owned(), "pipe-worker".into(), "detect".into()],
    )
    .unwrap();
    assert_eq!(inproc.frames, piped.frames);
    assert_eq!(inproc.exact_matches, piped.exact_matches, "pipe and in-process disagree");
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn scenario_campaign_service_end_to_end() {
    // The campaign engine runs on the CPU detection path: no artifacts
    // gate — this exercises generation, YARN-analog containers, DCE
    // sharding, bag materialization, replay scoring and aggregation.
    use adcloud::scenario;
    let p = Platform::local().unwrap();
    let specs = scenario::generate_campaign_sized(7, 12, 8);
    assert_eq!(specs.len(), 12);
    let hashes: std::collections::HashSet<u64> =
        specs.iter().map(|s| s.content_hash()).collect();
    assert_eq!(hashes.len(), 12, "specs must be distinct");
    // Same seed -> byte-identical canonical specs.
    let again = scenario::generate_campaign_sized(7, 12, 8);
    for (a, b) in specs.iter().zip(&again) {
        assert_eq!(a.canonical_json(), b.canonical_json());
    }
    let cfg = scenario::CampaignConfig::new("svc-campaign", 2);
    let report = scenario::run_campaign(&p.ctx, &p.resources, &specs, &cfg).unwrap();
    assert_eq!(report.scenarios, 12);
    assert_eq!(report.distinct_hashes, 12);
    assert!(report.passed >= 1, "clear-weather scenarios must qualify");
    assert!(report.families.len() >= 2, "grid families expected: {:?}", report.families);
    assert!(report.coverage.weather_covered >= 2);
    let rendered = report.render();
    assert!(rendered.contains("failure-rate"));
    assert!(rendered.contains("coverage"));
}
