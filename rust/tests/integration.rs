//! Cross-module integration tests: platform boot → engine → storage →
//! runtime → experiments, exercised together.

use adcloud::config::PlatformConfig;
use adcloud::dce::{BinaryRddExt, DceContext};
use adcloud::platform::{experiments, JobHandle, JobSpec, Platform};
use adcloud::resource::{DeviceKind, GrantTimeout, ResourceVec};
use adcloud::runtime::Tensor;
use std::time::Duration;

fn have_artifacts() -> bool {
    let ok = adcloud::artifacts_dir().join("manifest.json").is_file();
    if !ok {
        eprintln!("skipped: run `make artifacts` to enable artifact-gated tests");
    }
    ok
}

#[test]
fn full_platform_job_flow() {
    let p = Platform::local().unwrap();
    // app submission -> elastic grant -> sharded compute -> RAII release,
    // all through the unified job layer.
    let job = JobHandle::submit(
        &p.resources,
        JobSpec::new("it").containers(1, 2).resources(ResourceVec::cores(1, 1 << 20)),
    )
    .unwrap();
    let out = job
        .run_sharded(&p.ctx, (0..1000u64).collect(), |sctx, items: Vec<u64>| {
            sctx.run(|_| items.into_iter().map(|x| x * x).filter(|x| x % 2 == 0).collect())
        })
        .unwrap();
    let stats = job.finish();
    assert_eq!(out.len(), 500);
    assert!(stats.containers >= 1);
    assert!(stats.container_seconds > 0.0);
    assert_eq!(p.resources.live_containers(), 0);
}

#[test]
fn job_layer_releases_containers_when_a_shard_errs() {
    let p = Platform::local().unwrap();
    let job = JobHandle::submit(
        &p.resources,
        JobSpec::new("it-err").containers(1, 2).retries(0),
    )
    .unwrap();
    assert!(p.resources.live_containers() > 0);
    let r = job.run_sharded(
        &p.ctx,
        vec![1u32, 2, 3, 4],
        |_sctx, _items: Vec<u32>| -> adcloud::Result<Vec<u32>> { anyhow::bail!("shard exploded") },
    );
    assert!(r.is_err());
    drop(job);
    assert_eq!(
        p.resources.live_containers(),
        0,
        "RAII grant must return every container on the error path"
    );
    // The app name is freed for resubmission too.
    p.resources.submit_app("it-err", "default").unwrap();
    p.resources.remove_app("it-err").unwrap();
}

#[test]
fn job_layer_releases_containers_when_a_shard_panics() {
    let p = Platform::local().unwrap();
    let job = JobHandle::submit(
        &p.resources,
        JobSpec::new("it-panic").containers(1, 2).retries(0),
    )
    .unwrap();
    let r = job.run_sharded(
        &p.ctx,
        vec![1u32, 2],
        |_sctx, _items: Vec<u32>| -> adcloud::Result<Vec<u32>> {
            panic!("shard panicked on purpose")
        },
    );
    assert!(r.is_err(), "a panicking shard must surface as a job error, not a hang");
    drop(job);
    assert_eq!(
        p.resources.live_containers(),
        0,
        "RAII grant must return every container on the panic path"
    );
}

#[test]
fn job_layer_releases_containers_when_a_worker_panics() {
    let p = Platform::local().unwrap();
    let job = JobHandle::submit(
        &p.resources,
        JobSpec::new("it-worker").containers(1, 2).retries(0),
    )
    .unwrap();
    let r = job.run_per_container(|sctx| {
        if sctx.shard == 0 {
            panic!("worker 0 dies");
        }
        Ok(7u32)
    });
    assert!(r.is_err());
    let stats = job.finish();
    assert!(stats.containers >= 1);
    assert_eq!(p.resources.live_containers(), 0);
}

#[test]
fn forced_preemption_mid_shard_releases_the_victim_container() {
    let p = Platform::local().unwrap();
    let job = JobHandle::submit(
        &p.resources,
        JobSpec::new("it-preempt").containers(1, 1).retries(0),
    )
    .unwrap();
    let victim = job.containers()[0].clone();
    let victim_id = victim.id;
    let rm = p.resources.clone();
    let r = job.run_sharded(&p.ctx, vec![1u32, 2, 3], move |sctx, items: Vec<u32>| {
        if sctx.container().id == victim_id {
            // Mid-shard: have the scheduler preempt this very shard,
            // then yield at the next item boundary.
            assert_eq!(rm.request_preemption("it-preempt", 1), 1);
            sctx.check_preempted()?;
        }
        Ok(items)
    });
    assert_eq!(r.unwrap(), vec![1, 2, 3], "the requeued shard must still finish the work");
    assert!(victim.is_released(), "the victim container was released mid-job");
    assert_eq!(p.resources.live_containers(), 1, "only the replacement remains held");
    let stats = job.finish();
    assert_eq!(stats.preemptions, 1);
    assert_eq!(stats.shard_retries, 0, "preemption must not burn the retry budget");
    assert_eq!(p.resources.live_containers(), 0, "replacement released by the RAII grant");
}

#[test]
fn gang_floors_exceeding_the_cluster_queue_whole_or_time_out() {
    // 4 cores total: two floor-3 jobs cannot run concurrently. Gang
    // admission means the loser holds NOTHING while blocked — one job
    // admits, the other times out whole with a typed GrantTimeout —
    // instead of the 2+2 hold-and-wait deadlock the escalating
    // acquisition allowed.
    let p = Platform::local().unwrap();
    let spec = |app: &str, timeout_ms: u64| {
        JobSpec::new(app)
            .containers(3, 3)
            .resources(ResourceVec::cores(1, 1 << 20))
            .grant_timeout(Duration::from_millis(timeout_ms))
    };
    let winner = JobHandle::submit(&p.resources, spec("it-gang-a", 1000)).unwrap();
    assert_eq!(winner.shards(), 3);
    let loser = JobHandle::submit(&p.resources, spec("it-gang-b", 100));
    let e = loser.err().expect("second floor cannot be admitted");
    let t = e.downcast_ref::<GrantTimeout>().expect("timeout must be a typed GrantTimeout");
    assert_eq!(t.queue, "default");
    assert_eq!(t.deficit + t.grantable, 3, "the whole floor was still pending");
    assert_eq!(p.resources.live_containers(), 3, "the loser held nothing while waiting");
    let _ = winner.finish();
    // With the winner gone, the same floor admits immediately.
    let retry = JobHandle::submit(&p.resources, spec("it-gang-b", 1000)).unwrap();
    assert_eq!(retry.shards(), 3);
    let _ = retry.finish();
    assert_eq!(p.resources.live_containers(), 0);
}

#[test]
fn failed_campaign_returns_its_grant() {
    // End-to-end regression for the workload-level RAII behaviour: a
    // campaign whose shards all fail must not leak containers and must
    // leave the app name reusable.
    use adcloud::scenario;
    let p = Platform::local().unwrap();
    let specs = scenario::generate_campaign_sized(3, 4, 8);
    let mut cfg = scenario::CampaignConfig::new("it-badcamp", 2);
    // Point the work dir INSIDE an existing file so bag creation fails.
    let blocker = std::env::temp_dir().join(format!("adcloud-it-blocker-{}", std::process::id()));
    std::fs::write(&blocker, b"not a dir").unwrap();
    cfg.work_dir = blocker.join("nested");
    let r = scenario::run_campaign(&p.ctx, &p.resources, &specs, &cfg);
    assert!(r.is_err(), "campaign into an unwritable work dir must fail");
    assert_eq!(p.resources.live_containers(), 0, "failed campaign must return its grant");
    // Same config is immediately resubmittable (app name freed) — give
    // it a writable dir and it succeeds.
    cfg.work_dir = std::env::temp_dir().join(format!("adcloud-it-ok-{}", std::process::id()));
    let report = scenario::run_campaign(&p.ctx, &p.resources, &specs, &cfg).unwrap();
    assert_eq!(report.scenarios, 4);
    assert_eq!(p.resources.live_containers(), 0);
    let _ = std::fs::remove_file(&blocker);
}

#[test]
fn rdd_through_tiered_storage_with_lineage() {
    let ctx = DceContext::local().unwrap();
    let records: Vec<Vec<u8>> = (0..50u8).map(|i| vec![i; 128]).collect();
    let rdd = ctx.parallelize(records.clone(), 5);
    let persisted = rdd.persist_tiered("it/blocks").unwrap();
    // Data survives the round trip through the store.
    let mut got = persisted.collect().unwrap();
    got.sort();
    let mut want = records;
    want.sort();
    assert_eq!(got, want);
    // Blocks are durable after flush.
    ctx.store().flush();
    assert!(ctx.store().under().len() >= 5);
}

#[test]
fn shuffle_cache_and_storage_compose() {
    let ctx = DceContext::local().unwrap();
    let base = ctx.range(10_000, 8).map(|x| (x % 100, 1u64)).cache();
    let counts1 = base.reduce_by_key(|a, b| a + b, 4).collect().unwrap();
    let counts2 = base.reduce_by_key(|a, b| a + b, 8).collect().unwrap();
    let sum1: u64 = counts1.iter().map(|(_, n)| n).sum();
    let sum2: u64 = counts2.iter().map(|(_, n)| n).sum();
    assert_eq!(sum1, 10_000);
    assert_eq!(sum2, 10_000);
}

#[test]
fn artifacts_execute_from_integration_context() {
    if !have_artifacts() {
        return;
    }
    let p = Platform::local().unwrap();
    let rt = p.runtime.as_ref().unwrap();
    // Execute on every device server.
    for dev in 0..rt.num_devices() {
        let x = Tensor::from_f32(vec![0.1; 64 * 64], &[1, 64, 64]).unwrap();
        let out = rt.execute_on(dev, "feature_b1", vec![x]).unwrap();
        assert_eq!(out[0].shape, vec![1, 8, 8, 4]);
    }
}

#[test]
fn dispatcher_cross_device_consistency_through_platform() {
    if !have_artifacts() {
        return;
    }
    let p = Platform::local().unwrap();
    let mut rng = adcloud::util::Rng::new(77);
    let pts: Vec<f32> = (0..1024 * 3).map(|_| rng.normal_f32(0.0, 3.0)).collect();
    let qts: Vec<f32> = (0..1024 * 3).map(|_| rng.normal_f32(0.5, 3.0)).collect();
    let ins = vec![
        Tensor::from_f32(pts, &[1024, 3]).unwrap(),
        Tensor::from_f32(qts, &[1024, 3]).unwrap(),
    ];
    let gpu = p.dispatcher.run_on(DeviceKind::Gpu, "icp_step_1024", &ins).unwrap();
    let cpu = p.dispatcher.run_on(DeviceKind::Cpu, "icp_step_1024", &ins).unwrap();
    let (g, c) = (gpu[3].scalar_value().unwrap(), cpu[3].scalar_value().unwrap());
    assert!((g - c).abs() < 1e-2 * (1.0 + g.abs()), "{g} vs {c}");
}

#[test]
fn pipe_through_external_process_in_integration() {
    let ctx = DceContext::local().unwrap();
    let records: Vec<Vec<u8>> = (0..32u32).map(|i| i.to_le_bytes().to_vec()).collect();
    let out = ctx
        .parallelize(records.clone(), 4)
        .pipe_through(vec!["cat".into()])
        .collect()
        .unwrap();
    assert_eq!(out, records);
}

#[test]
fn quick_experiments_produce_paper_shapes() {
    if !have_artifacts() {
        return;
    }
    // E2: tiered must beat DFS.
    let t = experiments::run_experiment("e2", true).unwrap();
    let tiered_speedup: f64 = t.rows[0][3].trim_end_matches('x').parse().unwrap();
    assert!(tiered_speedup > 3.0, "tiered only {tiered_speedup}x over DFS");
    // E4: container overhead under 5%. This is a microsecond-scale
    // measurement smoke-checked under concurrent test load on one core,
    // so take the best of three attempts against a noise-padded bar
    // (the full bench run is the authoritative number).
    let overhead = (0..3)
        .map(|_| {
            let t = experiments::run_experiment("e4", true).unwrap();
            t.rows[1][2].trim_end_matches('%').parse::<f64>().unwrap()
        })
        .fold(f64::INFINITY, f64::min);
    assert!(overhead < 8.0, "container overhead {overhead}%");
    // E7: unified at least as fast as staged.
    let t = experiments::run_experiment("e7", true).unwrap();
    let speedup: f64 = t.rows[0][4].trim_end_matches('x').parse().unwrap();
    assert!(speedup >= 1.0, "unified slower than staged: {speedup}x");
}

#[test]
fn config_round_trips_through_file_and_boot() {
    let dir = std::env::temp_dir().join(format!("adcloud-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cluster.json");
    let mut cfg = PlatformConfig::test();
    cfg.cluster.nodes = 3;
    cfg.save(&path).unwrap();
    let loaded = PlatformConfig::load(&path).unwrap();
    assert_eq!(loaded.cluster.nodes, 3);
    let p = Platform::boot(loaded).unwrap();
    assert!(p.describe().contains("3 nodes"));
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn fault_injected_platform_still_correct() {
    let p = Platform::local().unwrap();
    use std::sync::Arc;
    p.ctx.set_fail_injector(Some(Arc::new(|tc| {
        if tc.attempt == 0 && tc.partition % 3 == 0 {
            anyhow::bail!("chaos");
        }
        Ok(())
    })));
    for _ in 0..5 {
        let n = p.ctx.range(500, 6).count().unwrap();
        assert_eq!(n, 500);
    }
    p.ctx.set_fail_injector(None);
}

#[test]
fn trace_spans_close_across_a_panicking_shard() {
    // Holds the tracer's test lock: the tracer is process-global, so
    // only one test in this binary may have it enabled at a time.
    let _g = adcloud::trace::testing::serial();
    let tracer = adcloud::trace::tracer();
    tracer.enable();
    tracer.clear();
    let p = Platform::local().unwrap();
    let job = JobHandle::submit(
        &p.resources,
        JobSpec::new("it-trace-panic").containers(1, 2).retries(0),
    )
    .unwrap();
    let root = job.trace();
    let r = job.run_sharded(
        &p.ctx,
        vec![1u32, 2],
        |_sctx, _items: Vec<u32>| -> adcloud::Result<Vec<u32>> {
            panic!("shard panicked on purpose")
        },
    );
    assert!(r.is_err());
    let _ = job.finish();
    let spans = tracer.spans_for(root.trace_id);
    tracer.disable();
    // The panicking attempt's span is recorded during unwind — its
    // presence in the archive IS closure; an orphan would be absent.
    assert!(
        spans.iter().any(|e| e.name == "job.shard"),
        "panicking shard attempts must still record their spans"
    );
    assert!(
        spans.iter().any(|e| e.span_id == root.span_id),
        "the job root span must close when the job is finished"
    );
    // Every non-root span's parent resolves inside the same trace: no
    // span was left dangling by the unwind.
    let ids: std::collections::HashSet<u64> = spans.iter().map(|e| e.span_id).collect();
    for e in &spans {
        if e.span_id != root.span_id {
            assert!(
                ids.contains(&e.parent_id),
                "span {} '{}' has unresolved parent {}",
                e.span_id,
                e.name,
                e.parent_id
            );
        }
        assert!(e.end_us >= e.start_us, "span '{}' closed before it opened", e.name);
    }
}

#[test]
fn trace_spans_close_across_preemption_requeue() {
    let _g = adcloud::trace::testing::serial();
    let tracer = adcloud::trace::tracer();
    tracer.enable();
    tracer.clear();
    let p = Platform::local().unwrap();
    let job = JobHandle::submit(
        &p.resources,
        JobSpec::new("it-trace-preempt").containers(1, 1).retries(0),
    )
    .unwrap();
    let root = job.trace();
    let victim_id = job.containers()[0].id;
    let rm = p.resources.clone();
    let r = job.run_sharded(&p.ctx, vec![1u32, 2, 3], move |sctx, items: Vec<u32>| {
        if sctx.container().id == victim_id {
            assert_eq!(rm.request_preemption("it-trace-preempt", 1), 1);
            sctx.check_preempted()?;
        }
        Ok(items)
    });
    assert_eq!(r.unwrap(), vec![1, 2, 3]);
    let stats = job.finish();
    assert_eq!(stats.preemptions, 1);
    let spans = tracer.spans_for(root.trace_id);
    tracer.disable();
    // Both the preempted attempt and its requeued successor closed,
    // and the requeue wait is a span of its own under the job root.
    let attempts = spans.iter().filter(|e| e.name == "job.shard").count();
    assert!(attempts >= 2, "preempted + requeued attempts must both record, got {attempts}");
    let requeue = spans
        .iter()
        .find(|e| e.name == "job.preempt_requeue")
        .expect("the requeue wait must be recorded");
    assert_eq!(requeue.parent_id, root.span_id);
    // The finished stats carry the same attribution the raw spans give,
    // and it partitions the job's makespan exactly.
    let cp = adcloud::trace::critical_path::analyze(&spans, root.span_id)
        .expect("the closed root span must be analyzable");
    assert_eq!(cp.sum_us(), cp.total_us);
    assert_eq!(
        stats.critical_path.expect("tracer on => stats attribution").total_us,
        cp.total_us
    );
}

#[test]
fn job_failure_dumps_a_parseable_flight_recorder_bundle() {
    // The global obs hook emits trace spans on watchdog transitions, so
    // hold the tracer's test lock like the other tracer-adjacent tests.
    let _g = adcloud::trace::testing::serial();
    let dir = std::env::temp_dir().join(format!("adcloud-it-obs-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let p = Platform::local().unwrap();
    let obs = adcloud::obs::Observability::start(
        p.resources.metrics().clone(),
        adcloud::obs::ObsConfig { bundle_dir: Some(dir.clone()), ..Default::default() },
    );
    adcloud::obs::install(&obs);
    let job = JobHandle::submit(
        &p.resources,
        JobSpec::new("it-flightrec").containers(1, 2).retries(0),
    )
    .unwrap();
    let r = job.run_sharded(
        &p.ctx,
        vec![1u32, 2, 3, 4],
        |_sctx, _items: Vec<u32>| -> adcloud::Result<Vec<u32>> {
            anyhow::bail!("sensor fusion diverged")
        },
    );
    assert!(r.is_err());
    let _ = job.finish();
    adcloud::obs::uninstall();
    assert!(obs.bundles_captured() >= 1, "a failing job must capture a post-mortem bundle");
    obs.stop();
    // The bundle landed on disk; round-trip it through the reader the
    // `adcloud postmortem` command uses.
    let bundle_path = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .find(|path| {
            path.file_name()
                .map(|n| n.to_string_lossy().starts_with("postmortem-"))
                .unwrap_or(false)
        })
        .expect("a postmortem-*.json bundle must be written into bundle_dir");
    let bundle = adcloud::obs::recorder::load(&bundle_path).unwrap();
    let reason = bundle.req("reason").unwrap().as_str().unwrap();
    assert!(reason.contains("it-flightrec"), "bundle reason must name the failed job: {reason}");
    assert!(reason.contains("sensor fusion diverged"), "bundle reason must carry the error");
    assert!(bundle.req("series").is_ok(), "bundle must embed the sampled series");
    assert!(bundle.req("rules").is_ok(), "bundle must embed the rule states");
    assert!(bundle.req("spans").is_ok(), "bundle must embed the recent span archive");
    let rendered = adcloud::obs::recorder::render(&bundle).unwrap();
    assert!(rendered.contains("it-flightrec"), "rendered post-mortem must name the job");
    let _ = std::fs::remove_dir_all(&dir);
}
