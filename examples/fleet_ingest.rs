//! Fleet data plane end to end: a simulated fleet uploads telemetry and
//! rosbag chunks through the ingest gateway into the partitioned log,
//! container-granted compactors drain the partitions into tiered-storage
//! blocks (with lineage registered for recovery), the miner digs
//! hard-brake / disengagement / sensor-dropout events out of the
//! compacted drives, and the emitted scenario families run through the
//! campaign engine unmodified.
//!
//!     cargo run --release --example fleet_ingest [vehicles] [ticks] [partitions] [workers]

use adcloud::ingest;
use adcloud::platform::Platform;
use adcloud::scenario;
use adcloud::Result;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let vehicles: u32 = args.first().and_then(|s| s.parse().ok()).unwrap_or(16);
    let ticks: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let partitions: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);
    let workers: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(2);

    let platform = Platform::boot(adcloud::config::PlatformConfig::default())?;
    println!("{}", platform.describe());

    // 1. Fleet -> gateway -> partitioned log.
    let log = ingest::PartitionedLog::temp(
        "example",
        ingest::LogConfig { partitions, ..Default::default() },
    )?;
    let gateway = ingest::IngestGateway::new(
        log.clone(),
        ingest::GatewayConfig::default(),
        platform.metrics.clone(),
    );
    let mut fleet_cfg = ingest::FleetConfig::new(vehicles, ticks, platform.config.seed);
    fleet_cfg.corrupt_rate = 0.02;
    let fleet = ingest::simulate_fleet(&gateway, &fleet_cfg)?;
    println!("{}", fleet.render());
    for d in gateway.dead_letters().iter().take(2) {
        println!("  dead letter: vehicle {} at {} ns — {}", d.vehicle, d.ts_ns, d.reason);
    }

    // 2. Compaction: log partitions -> tiered-store blocks + lineage.
    let compaction = ingest::compact(
        &log,
        platform.ctx.store(),
        &platform.resources,
        &ingest::CompactorConfig::new("fleet-ingest-ex", workers),
    )?;
    println!("{}", compaction.render());
    for p in 0..log.partitions() {
        println!(
            "  partition {p}: head {} committed {} (lag {})",
            log.next_offset(p),
            log.committed(p),
            log.lag(p)
        );
    }

    // 3. Mining: compacted drives -> scenario families.
    let mined = ingest::mine(
        &platform.ctx,
        &platform.resources,
        platform.ctx.store(),
        &compaction.blocks,
        &ingest::MinerConfig::default(),
    )?;
    print!("{}", mined.render());

    // 4. Close the loop: the mined families run as a campaign.
    let specs: Vec<_> = mined.specs.iter().take(12).cloned().collect();
    if !specs.is_empty() {
        let cfg = scenario::CampaignConfig::new("fleet-mined", workers);
        let report = scenario::run_campaign(&platform.ctx, &platform.resources, &specs, &cfg)?;
        println!("{}", report.render());
    }
    println!("fleet_ingest done");
    Ok(())
}
