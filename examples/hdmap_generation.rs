//! HD map generation (paper §5): drive a synthetic ring road, recover
//! poses with SLAM (odometry propagation + GPS correction + accelerated
//! ICP), build the 5 cm-class grid map, add semantic layers, then use
//! the map to localise.
//!
//!     cargo run --release --example hdmap_generation [steps]

use adcloud::platform::Platform;
use adcloud::services::mapgen;
use adcloud::Result;

fn main() -> Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    let platform = Platform::boot(adcloud::config::PlatformConfig::default())?;
    println!("{}", platform.describe());
    anyhow::ensure!(
        platform.has_accelerators(),
        "this example needs the AOT artifacts — run `make artifacts` first"
    );

    println!("generating world + {steps}-step drive log...");
    let world = mapgen::gen_world(platform.config.seed);
    let log = mapgen::gen_drive(&world, steps, platform.config.seed);
    let scan_pts: usize = log.scans.iter().map(|s| s.len() / 3).sum();
    println!("  {} landmarks, {} scan points logged", world.landmarks.len() / 3, scan_pts);

    // Dead reckoning baseline: how far odometry alone drifts.
    let dr = mapgen::dead_reckon(log.poses_gt[0], &log.odom);
    println!(
        "dead-reckoning drift: {:.2} m mean error",
        mapgen::slam::mean_err(&dr, &log.poses_gt)
    );

    // The full fused pipeline (Figure 10).
    let cfg = mapgen::SlamConfig::default();
    let opts = adcloud::platform::JobOpts::new("mapgen-fused");
    let report =
        mapgen::run_fused(&platform.dispatcher, &platform.resources, &log, &cfg, &opts, 0.1)?;
    println!(
        "fused pipeline in {}: slam err {:.2} m, {} occupied cells, {} lane samples, {} signs",
        adcloud::util::fmt_duration(report.elapsed),
        report.slam_err_m,
        report.occupied_cells,
        report.lanes,
        report.signs
    );

    // Use the map the way a vehicle would (paper §5.1): perturb a pose,
    // localise against the grid.
    let truth = log.poses_gt[steps / 2];
    let perturbed = adcloud::pointcloud::Se3::new(
        truth.r,
        [truth.t[0] + 0.3, truth.t[1] - 0.3, truth.t[2]],
    );
    let (refined, score) = report.map.localize(&log.scans[steps / 2], &perturbed);
    let before = adcloud::pointcloud::v_norm(adcloud::pointcloud::v_sub(perturbed.t, truth.t));
    let after = adcloud::pointcloud::v_norm(adcloud::pointcloud::v_sub(refined.t, truth.t));
    println!("localisation: {before:.2} m -> {after:.2} m error (match score {score:.2})");

    // Semantic queries.
    if let Some((sign, dist)) = report.map.nearest_sign(truth.t[0], truth.t[1]) {
        println!("nearest sign: {} at {:.1} m", sign.kind, dist);
    }
    println!(
        "on-lane check at vehicle: {}, at world origin: {}",
        report.map.on_lane(truth.t[0], truth.t[1]),
        report.map.on_lane(0.0, 0.0)
    );
    println!("hdmap_generation done");
    Ok(())
}
