//! Quickstart: boot the platform, run a distributed job, call an
//! accelerator kernel, inspect metrics.
//!
//!     cargo run --release --example quickstart

use adcloud::platform::Platform;
use adcloud::runtime::Tensor;
use adcloud::services::sql;
use adcloud::Result;

fn main() -> Result<()> {
    // 1. Boot the unified infrastructure (Figure 2 of the paper):
    //    resource manager + tiered storage + compute engine + PJRT
    //    accelerator runtime.
    let platform = Platform::boot(adcloud::config::PlatformConfig::default())?;
    println!("{}", platform.describe());

    // 2. Distributed computing: a telemetry aggregation over the
    //    Spark-analog engine.
    let telemetry = sql::generate_telemetry(50_000, 100, 42);
    let rdd = platform.ctx.parallelize(telemetry, 8).cache();
    let per_vehicle = sql::q1_dce(&rdd, 8)?;
    println!("q1: mean speed for {} vehicles (zone < 8)", per_vehicle.len());

    // 3. Distributed storage: put a block through the tiered store and
    //    read it back at memory speed.
    platform.ctx.store().put("quickstart/block", vec![1u8; 1 << 20])?;
    let blk = platform.ctx.store().get("quickstart/block")?;
    println!(
        "tiered store round-trip: {} bytes, tier {:?}",
        blk.len(),
        platform.ctx.store().tier_of("quickstart/block")
    );

    // 4. Heterogeneous computing: run the feature kernel on the best
    //    available device class (GPU-class PJRT artifact if built).
    if platform.has_accelerators() {
        let image = Tensor::from_f32(vec![0.5; 64 * 64], &[1, 64, 64])?;
        let (device, out) = platform.dispatcher.run_best("feature_b1", &[image], &[])?;
        println!("feature kernel on {device}: {:?} descriptors", out[0].shape);
    } else {
        println!("(artifacts not built — run `make artifacts` for accelerator kernels)");
    }

    // 5. Metrics.
    println!("\n{}", platform.ctx.metrics().report());
    println!("quickstart done");
    Ok(())
}
