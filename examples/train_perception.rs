//! End-to-end validation driver (DESIGN.md §5): boot the full platform,
//! generate a synthetic labelled driving-image corpus, run the unified
//! ETL→feature→train pipeline with real distributed SGD through the AOT
//! train-step artifact, and log the loss curve + throughput.
//!
//!     cargo run --release --example train_perception [examples] [rounds]

use adcloud::hetero::cpu_impls::init_params;
use adcloud::platform::{JobHandle, JobSpec, Platform};
use adcloud::resource::{DeviceKind, ResourceVec};
use adcloud::services::training::{self, ParamServer};
use adcloud::util::Rng;
use adcloud::Result;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_examples: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(2048);
    let rounds: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let workers = 4usize;

    let platform = Platform::boot(adcloud::config::PlatformConfig::default())?;
    println!("{}", platform.describe());
    anyhow::ensure!(
        platform.has_accelerators(),
        "this example needs the AOT artifacts — run `make artifacts` first"
    );

    // Ask for GPU-backed containers through the unified job layer, as
    // every platform workload does (paper §2.3): one JobSpec, an
    // elastic grant, RAII release.
    let job = JobHandle::submit(
        &platform.resources,
        JobSpec::new("train-perception")
            .containers(1, platform.config.cluster.nodes.min(workers))
            .resources(ResourceVec::cores(1, 128 << 20).with_gpu(1)),
    )?;
    println!("granted {} GPU containers", job.shards());

    // Data: synthetic 10-class labelled corpus, sharded per worker.
    println!("generating {n_examples} labelled examples...");
    let data = training::gen_dataset(n_examples, platform.config.seed);
    let shards = training::shard(data, workers);

    // Parameter server on the tiered store (the paper's Alluxio PS).
    let ps = ParamServer::tiered(platform.ctx.store().clone(), "train-perception");
    let trainer =
        training::DistTrainer::new(platform.dispatcher.clone(), DeviceKind::Gpu, shards);
    let init = init_params(&mut Rng::new(platform.config.seed));

    println!("training: {rounds} rounds x {workers} workers x batch {}...", training::BATCH);
    let report = trainer.train(&ps, init, rounds, 0.05)?;

    println!("\nloss curve (every {}th round):", (rounds / 20).max(1));
    for r in report.rounds.iter().step_by((rounds / 20).max(1)) {
        let bar = "#".repeat((r.mean_loss * 20.0).min(60.0) as usize);
        println!("  round {:>4}  loss {:>7.4}  {bar}", r.round, r.mean_loss);
    }
    println!(
        "\nloss {:.4} -> {:.4} over {} rounds; {:.0} examples/s end-to-end",
        report.first_loss(),
        report.last_loss(),
        rounds,
        report.throughput
    );
    anyhow::ensure!(
        report.last_loss() < report.first_loss(),
        "loss did not decrease — training is broken"
    );

    let stats = job.finish();
    println!("\n{}", stats.render());
    println!("{}", platform.dispatcher.energy().joules(DeviceKind::Gpu));
    println!("{}", platform.metrics.report());
    println!("train_perception done (recorded in EXPERIMENTS.md)");
    Ok(())
}
