//! Scenario-engine campaign: procedurally generate a family of test
//! scenarios (parameter-grid sweep + seeded mutations), shard them
//! across the compute engine inside YARN-analog containers, replay
//! each through the obstacle detector, and print the qualification
//! report — coverage and per-family failure rates.
//!
//!     cargo run --release --example scenario_campaign [seed] [scenarios] [nodes]

use adcloud::platform::Platform;
use adcloud::scenario;
use adcloud::Result;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let seed: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(7);
    let scenarios: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(32);
    let nodes: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);

    let platform = Platform::boot(adcloud::config::PlatformConfig::default())?;
    println!("{}", platform.describe());

    let specs = scenario::generate_campaign(seed, scenarios);
    let digest = scenario::campaign_digest(&specs);
    println!(
        "generated {} scenarios from seed {seed} (spec digest {digest:016x} — rerun to verify reproducibility)",
        specs.len()
    );
    for s in specs.iter().take(3) {
        println!(
            "  {} [{}]: {:?}, {} actors, noise {}, route {:.0} m",
            s.id,
            s.family,
            s.weather,
            s.actors.len(),
            s.pixel_noise,
            s.route.length_m()
        );
    }
    println!("  ...");

    let cfg = scenario::CampaignConfig::new(format!("campaign-ex-{seed}"), nodes);
    let report = scenario::run_campaign(&platform.ctx, &platform.resources, &specs, &cfg)?;
    println!("{}", report.render());

    // The report also emits JSON for archival/release gating.
    println!("report json: {}", report.to_json().to_string());
    println!("scenario_campaign done");
    Ok(())
}
