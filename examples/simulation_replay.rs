//! Distributed simulation replay (paper §3): record a synthetic drive
//! into bag files, then qualify a detection algorithm two ways — in
//! process on the GPU-class kernel, and through *real Unix pipes* to
//! worker processes (the paper's Spark↔ROS bridge, §3.2).
//!
//!     cargo run --release --example simulation_replay [bags] [frames]

use adcloud::platform::Platform;
use adcloud::resource::DeviceKind;
use adcloud::services::simulation;
use adcloud::Result;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let bags_n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(8);
    let frames: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(32);

    let platform = Platform::boot(adcloud::config::PlatformConfig::default())?;
    println!("{}", platform.describe());

    let dir = std::env::temp_dir().join(format!("adcloud-replay-ex-{}", std::process::id()));
    println!("recording drive: {bags_n} bag chunks x {frames} frames...");
    let bags = simulation::record_drive(&dir, bags_n, frames, platform.config.seed)?;
    let total: u64 = bags.iter().map(|b| std::fs::metadata(b).map(|m| m.len()).unwrap_or(0)).sum();
    println!("  {} bags, {} total", bags.len(), adcloud::util::fmt_bytes(total));

    // Mode 1: in-process detection through the hetero dispatcher.
    if platform.has_accelerators() {
        let report =
            simulation::replay(&platform.ctx, &platform.dispatcher, &bags, DeviceKind::Gpu)?;
        println!(
            "in-process replay on {}: {}/{} frames exact ({:.1}%) in {}",
            report.device,
            report.exact_matches,
            report.frames,
            report.accuracy * 100.0,
            adcloud::util::fmt_duration(report.elapsed)
        );
    }

    // Mode 2: the BinPipeRDD bridge — frames stream over real pipes to
    // `adcloud pipe-worker detect` child processes.
    let exe = std::env::current_exe()?;
    let worker = exe
        .parent()
        .and_then(|p| p.parent())
        .map(|p| p.join("adcloud"))
        .filter(|p| p.is_file());
    match worker {
        Some(worker) => {
            let report = simulation::replay_piped(
                &platform.ctx,
                &bags,
                vec![worker.to_string_lossy().into_owned(), "pipe-worker".into(), "detect".into()],
            )?;
            println!(
                "piped replay (real Unix pipes): {}/{} frames exact ({:.1}%) in {}",
                report.exact_matches,
                report.frames,
                report.accuracy * 100.0,
                adcloud::util::fmt_duration(report.elapsed)
            );
        }
        None => println!(
            "(adcloud binary not found next to example — build with `cargo build --release` for the piped mode)"
        ),
    }

    let _ = std::fs::remove_dir_all(dir);
    println!("simulation_replay done");
    Ok(())
}
