//! The unified job layer, multi-tenant: two workloads — a scenario
//! campaign and a fleet-compaction drain — run **concurrently** on one
//! cluster through the same `JobSpec`/`JobHandle` API, against
//! capacity-share queues (sim 50% / fleet 50%). The capacity scheduler
//! caps each queue at half the cores so neither tenant can starve the
//! other; the job layer's RAII grants guarantee every container is
//! back in the pool when both jobs finish.
//!
//!     cargo run --release --example unified_jobs [nodes] [scenarios] [vehicles]

use adcloud::dce::DceContext;
use adcloud::ingest;
use adcloud::metrics::MetricsRegistry;
use adcloud::platform::experiments;
use adcloud::resource::ResourceManager;
use adcloud::scenario;
use adcloud::Result;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let nodes: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let scenarios: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(16);
    let vehicles: u32 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(8);

    let mut cfg = adcloud::config::PlatformConfig::default();
    cfg.cluster.nodes = nodes;
    let metrics = MetricsRegistry::new();
    let rm = ResourceManager::with_queues(
        &cfg.cluster,
        vec![("sim".into(), 0.5), ("fleet".into(), 0.5)],
        metrics.clone(),
    );
    let ctx = DceContext::new(cfg.clone())?;
    println!(
        "cluster: {} nodes x {} cores; queues sim=0.5 fleet=0.5",
        cfg.cluster.nodes, cfg.cluster.cores_per_node
    );

    // Fleet tenant: vehicles upload through the gateway into the
    // partitioned log the compaction job drains.
    let log = ingest::PartitionedLog::temp(
        "unified-jobs",
        ingest::LogConfig { partitions: nodes.max(2), ..Default::default() },
    )?;
    let gw =
        ingest::IngestGateway::new(log.clone(), ingest::GatewayConfig::default(), metrics.clone());
    let fleet = ingest::simulate_fleet(&gw, &ingest::FleetConfig::new(vehicles, 200, cfg.seed))?;
    println!("{}", fleet.render());

    // Sim tenant: a procedurally generated campaign.
    let specs = scenario::generate_campaign_sized(cfg.seed, scenarios, 16);
    let mut campaign_cfg = scenario::CampaignConfig::new("unified-campaign", nodes);
    campaign_cfg.opts.queue = "sim".into();
    let mut compactor_cfg = ingest::CompactorConfig::new("unified-compact", nodes);
    compactor_cfg.opts.queue = "fleet".into();

    // run_tenant_pair launches both jobs concurrently and verifies
    // every grant is back in the pool when they finish.
    let run = experiments::run_tenant_pair(
        &ctx,
        &rm,
        &specs,
        &campaign_cfg,
        &log,
        ctx.store(),
        &compactor_cfg,
        std::time::Duration::ZERO,
    )?;
    println!("{}", run.campaign.render());
    println!("{}", run.compaction.render());
    println!(
        "both tenants done in {} (campaign {}, compaction {})",
        adcloud::util::fmt_duration(run.makespan),
        adcloud::util::fmt_duration(run.campaign_elapsed),
        adcloud::util::fmt_duration(run.compaction_elapsed),
    );
    println!("job-layer metrics:\n{}", metrics.report());
    println!("unified_jobs done");
    Ok(())
}
