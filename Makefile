# The `make artifacts` target every artifact-gated test and CLI message
# points at: AOT-lower the Pallas/JAX kernels to HLO + manifest.json.
# Requires a Python environment with jax installed; the Rust side
# degrades gracefully (CPU reference kernels) when artifacts are absent.

.PHONY: artifacts test bench verify

artifacts:
	python3 python/compile/aot.py

test:
	cargo test -q

# Tier-1 gate (what CI runs): format check + release build + full test
# suite. The tree is rustfmt-formatted as of PR 4; keep it that way.
verify:
	cargo fmt --check && cargo build --release && cargo test -q

bench:
	ADCLOUD_BENCH_QUICK=1 cargo bench
