"""Pallas nearest-correspondence kernel: the ICP hot spot (paper section 5.2).

The paper reports a 30x GPU speedup for the Generalized-ICP point-cloud
alignment core of HD map generation. The dominant cost of one ICP
iteration is the correspondence search: for every source point, the
nearest destination point. On a GPU this is a work-group per source tile
brute-forcing the distance matrix; the TPU rethink keeps the full (small)
destination cloud resident in VMEM and walks source tiles through the
grid, fusing the distance computation with the argmin reduction so the
(BN x M) distance tile never leaves VMEM.

VMEM estimate (DESIGN.md section Perf): for M = 4096 destination points a
128-row source tile needs 128*4096*4 B = 2 MiB for the distance tile plus
48 KiB for the clouds -- fits with double buffering.

Outputs are the squared distance and the *gathered nearest point* itself
(not the index): gathers over VMEM rows are cheap here, and returning the
points lets the L2 graph compute centroids and the cross-covariance
without a second pass over HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _icp_kernel(src_ref, dst_ref, near_ref, d2_ref, *, m: int):
    """One grid step: nearest dst point for a tile of src points.

    src_ref:  (BN, 3) source tile
    dst_ref:  (M, 3) full destination cloud (VMEM-resident)
    near_ref: (BN, 3) out -- nearest destination point per source point
    d2_ref:   (BN,)  out -- squared distance to it
    """
    s = src_ref[...].astype(jnp.float32)          # (BN, 3)
    d = dst_ref[...].astype(jnp.float32)          # (M, 3)
    # ||s - d||^2 = ||s||^2 - 2 s.d + ||d||^2, computed as one fused tile.
    s2 = jnp.sum(s * s, axis=1, keepdims=True)    # (BN, 1)
    d2 = jnp.sum(d * d, axis=1)[None, :]          # (1, M)
    cross = jnp.dot(s, d.T, preferred_element_type=jnp.float32)  # (BN, M)
    dist = s2 - 2.0 * cross + d2                  # (BN, M)
    dmin = jnp.min(dist, axis=1, keepdims=True)   # (BN, 1)
    # Nearest-point selection WITHOUT argmin/gather: a {0,1} mask matmul
    # (ties average — harmless for alignment statistics). min+matmul map
    # onto fast reduce/MXU paths on every backend, whereas variadic
    # argmin + gather are serial sorts on the old XLA CPU runtime.
    mask = (dist <= dmin).astype(jnp.float32)     # (BN, M)
    counts = jnp.sum(mask, axis=1, keepdims=True)  # (BN, 1) >= 1
    near = jnp.dot(mask, d, preferred_element_type=jnp.float32) / counts
    d2_ref[...] = jnp.maximum(dmin[:, 0], 0.0).astype(d2_ref.dtype)
    near_ref[...] = near.astype(near_ref.dtype)


def icp_correspondences_pallas(
    src: jax.Array, dst: jax.Array, block_n: int | None = None
) -> tuple[jax.Array, jax.Array]:
    """Nearest-neighbour correspondences for ICP.

    src: (N, 3) float32, N divisible by block_n
    dst: (M, 3) float32
    Returns (nearest (N, 3), squared distances (N,)).

    Default blocking: the largest power-of-two tile <= 1024 dividing N.
    Large tiles keep the distance matmul MXU-efficient and, on the CPU
    interpret path, minimise grid iterations; a real-TPU build would cap
    the tile by VMEM (128 rows x M=4096 is 2 MiB — see DESIGN.md §Perf).
    """
    n, three = src.shape
    if block_n is None:
        block_n = 1024
        while block_n > 1 and n % block_n != 0:
            block_n //= 2
    assert three == 3, f"expected (N,3) source cloud, got {src.shape}"
    m = dst.shape[0]
    assert n % block_n == 0, f"N={n} not divisible by block {block_n}"
    kern = functools.partial(_icp_kernel, m=m)
    return pl.pallas_call(
        kern,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, 3), lambda i: (i, 0)),
            pl.BlockSpec((m, 3), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, 3), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 3), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(src, dst)
