"""Pure-jnp correctness oracles for every Pallas kernel.

Each function here is the straightforward, obviously-correct formulation
(lax.conv for conv2d, full brute-force distance matrix for ICP, direct
stencil math for features). pytest + hypothesis assert the Pallas kernels
match these to float32 tolerance across swept shapes; these oracles are
also what the AOT pipeline's L2 graphs are validated against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def conv2d_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """SAME conv2d, NHWC x HWIO -> NHWC, via lax.conv_general_dilated."""
    return lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def icp_correspondences_ref(
    src: jax.Array, dst: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Brute-force nearest neighbours: (nearest (N,3), squared dist (N,))."""
    s = src.astype(jnp.float32)
    d = dst.astype(jnp.float32)
    diff = s[:, None, :] - d[None, :, :]          # (N, M, 3)
    dist = jnp.sum(diff * diff, axis=-1)          # (N, M)
    idx = jnp.argmin(dist, axis=1)
    return jnp.take(d, idx, axis=0), jnp.min(dist, axis=1)


def feature_extract_ref(x: jax.Array) -> jax.Array:
    """Gradient-energy descriptors, direct formulation (see feature.py)."""
    cell = 8
    b, h, w = x.shape
    xp = jnp.pad(
        x.astype(jnp.float32), ((0, 0), (1, 1), (1, 1)), mode="edge"
    )
    gx = (xp[:, 1:-1, 2:] - xp[:, 1:-1, :-2]) * 0.5
    gy = (xp[:, 2:, 1:-1] - xp[:, :-2, 1:-1]) * 0.5
    mag = jnp.sqrt(gx * gx + gy * gy)
    ch, cw = h // cell, w // cell

    def cells(a):
        return a.reshape(b, ch, cell, cw, cell)

    f0 = jnp.mean(jnp.abs(cells(gx)), axis=(2, 4))
    f1 = jnp.mean(jnp.abs(cells(gy)), axis=(2, 4))
    f2 = jnp.mean(cells(mag), axis=(2, 4))
    f3 = jnp.max(cells(mag), axis=(2, 4))
    return jnp.stack([f0, f1, f2, f3], axis=-1)
