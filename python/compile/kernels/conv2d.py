"""Pallas SAME-padding 2-D convolution (NHWC), the paper's CNN hot spot.

The paper (sections 2.3 and 4.3) offloads CNN convolutions to GPU via
OpenCL and reports 10-20x over CPU. On TPU-shaped hardware the right
formulation is not a thread-per-output-pixel work-group but a blocked
matmul: for each (kh, kw) tap, a (H*W, Cin) x (Cin, Cout) matmul feeding
the MXU systolic array, accumulated in VMEM. The grid walks the batch;
each grid step holds one padded image plus the full filter bank in VMEM.

VMEM budget (estimate recorded for DESIGN.md section Perf): for the
32x32x16 training layer the padded block is 34*34*16*4 B = 74 KiB, the
filters 3*3*16*16*4 B = 9 KiB and the accumulator 32*32*16*4 B = 64 KiB
-- comfortably inside a 16 MiB VMEM, leaving room for double buffering.

``interpret=True`` always: the CPU PJRT plugin cannot run Mosaic
custom-calls, and interpret-mode lowers to plain HLO that the Rust
runtime executes unmodified.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv2d_kernel(x_ref, w_ref, o_ref, *, batch: int, height: int,
                   width: int, kh: int, kw: int, cin: int, cout: int):
    """One grid step: full SAME conv for a batch block.

    x_ref: (B, H+kh-1, W+kw-1, Cin) padded input block (VMEM)
    w_ref: (kh, kw, Cin, Cout) filter bank (VMEM, replicated per step)
    o_ref: (B, H, W, Cout) output block

    Whole-batch blocks maximise the per-tap matmul's M dimension
    (B*H*W rows feeding the MXU) and avoid per-image grid iterations —
    on the CPU interpret path that removes the while-loop +
    dynamic-slice overhead entirely. A real-TPU build would re-block
    the batch to the VMEM budget (see DESIGN.md §Perf: the training
    layer block is ~2.5 MiB at B=32, far under a 16 MiB VMEM).
    """
    acc = jnp.zeros((batch * height * width, cout), dtype=jnp.float32)
    # Static unroll over filter taps: each tap is one MXU matmul.
    for i in range(kh):
        for j in range(kw):
            xs = x_ref[:, i:i + height, j:j + width, :]
            xs = xs.reshape(batch * height * width, cin).astype(jnp.float32)
            wt = w_ref[i, j].astype(jnp.float32)
            acc = acc + jnp.dot(xs, wt, preferred_element_type=jnp.float32)
    o_ref[...] = acc.reshape(batch, height, width, cout).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=())
def conv2d_pallas(x: jax.Array, w: jax.Array) -> jax.Array:
    """SAME conv2d, NHWC x HWIO -> NHWC, via the Pallas kernel.

    x: (B, H, W, Cin) float32
    w: (KH, KW, Cin, Cout) float32
    """
    b, h, wd, cin = x.shape
    kh, kw, cin_w, cout = w.shape
    assert cin == cin_w, f"channel mismatch {cin} vs {cin_w}"
    # XLA SAME-padding split: low = (k-1)//2, high = k-1-low.
    ph, pw = (kh - 1) // 2, (kw - 1) // 2
    xp = jnp.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0)))
    kern = functools.partial(
        _conv2d_kernel, batch=b, height=h, width=wd, kh=kh, kw=kw, cin=cin,
        cout=cout,
    )
    return pl.pallas_call(
        kern,
        grid=(1,),
        in_specs=[
            pl.BlockSpec(
                (b, h + kh - 1, wd + kw - 1, cin), lambda i: (0, 0, 0, 0)
            ),
            pl.BlockSpec((kh, kw, cin, cout), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((b, h, wd, cout), lambda i: (0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, wd, cout), x.dtype),
        interpret=True,
    )(xp, w)


# ---------------------------------------------------------------------------
# Differentiable wrapper: Pallas forward, conv-expressed backward.
#
# d/dx of a SAME correlation is a SAME correlation of the cotangent with the
# spatially-flipped, channel-transposed filters -- so the data gradient
# reuses the very same Pallas kernel (it appears in the backward HLO too).
# The filter gradient is a patch-contraction einsum left to XLA, which fuses
# it into one loop nest.
# ---------------------------------------------------------------------------


@jax.custom_vjp
def conv2d(x: jax.Array, w: jax.Array) -> jax.Array:
    """Differentiable SAME conv2d whose forward is the Pallas kernel."""
    return conv2d_pallas(x, w)


def _conv2d_fwd(x, w):
    return conv2d_pallas(x, w), (x, w)


def _extract_patches(x: jax.Array, kh: int, kw: int) -> jax.Array:
    """(B,H,W,Cin) -> (B,H,W,kh,kw,Cin) SAME-padded sliding patches."""
    b, h, wd, cin = x.shape
    ph, pw = (kh - 1) // 2, (kw - 1) // 2
    xp = jnp.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0)))
    rows = [
        jnp.stack([xp[:, i:i + h, j:j + wd, :] for j in range(kw)], axis=3)
        for i in range(kh)
    ]
    return jnp.stack(rows, axis=3)  # (B,H,W,kh,kw,Cin)


def _conv2d_bwd(res, g):
    x, w = res
    kh, kw = w.shape[0], w.shape[1]
    # Odd taps only: even-kernel SAME needs a swapped pad split in the
    # transpose. The model's filters are all 3x3; inference-only paths may
    # still use even kernels through conv2d_pallas directly.
    assert kh % 2 == 1 and kw % 2 == 1, "conv2d vjp requires odd kernels"
    # dx: correlate cotangent with flipped filters, Cin/Cout swapped.
    w_flip = jnp.transpose(w[::-1, ::-1, :, :], (0, 1, 3, 2))
    dx = conv2d_pallas(g, w_flip)
    # dw: contract sliding patches of x against the cotangent.
    patches = _extract_patches(x, kh, kw)  # (B,H,W,kh,kw,Cin)
    dw = jnp.einsum("bhwijc,bhwo->ijco", patches, g)
    return dx.astype(x.dtype), dw.astype(w.dtype)


conv2d.defvjp(_conv2d_fwd, _conv2d_bwd)
