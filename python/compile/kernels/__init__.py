"""Layer-1 Pallas kernels for the adcloud platform.

These are the numeric hot spots the paper offloads to OpenCL devices
(GPU/FPGA); here they are authored as Pallas kernels, lowered with
``interpret=True`` (the CPU PJRT backend cannot execute Mosaic
custom-calls), and AOT-compiled into the HLO artifacts the Rust
coordinator executes through PJRT.

Kernels:
  conv2d   -- blocked im2col-style convolution (MXU-shaped matmuls)
  icp      -- nearest-correspondence search for ICP point-cloud alignment
  feature  -- image gradient feature extraction (Fig 6 workload)
"""

from .conv2d import conv2d_pallas, conv2d
from .icp import icp_correspondences_pallas
from .feature import feature_extract_pallas

__all__ = [
    "conv2d_pallas",
    "conv2d",
    "icp_correspondences_pallas",
    "feature_extract_pallas",
]
