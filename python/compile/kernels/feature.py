"""Pallas image-feature-extraction kernel: the Fig 6 simulation workload.

Section 3.3 of the paper scales "basic image feature extraction tasks on
one million images" from 2,000 to 10,000 CPU cores. The per-image kernel
here is a gradient-energy descriptor: central-difference gradients, then
per-cell (8x8) pooling of mean |gx|, mean |gy|, mean magnitude and max
magnitude -- the kind of cheap dense stencil + reduction that dominates
such pipelines.

TPU formulation: one padded grayscale image per grid step lives in VMEM;
the stencil and the pooling reductions fuse into a single pass, so HBM
traffic is exactly one image in, one (H/8, W/8, 4) descriptor out.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CELL = 8
FEATS = 4


def _feature_kernel(x_ref, o_ref, *, h: int, w: int):
    """x_ref: (1, H+2, W+2) padded image; o_ref: (1, H/8, W/8, 4)."""
    xp = x_ref[0].astype(jnp.float32)             # (H+2, W+2)
    gx = (xp[1:-1, 2:] - xp[1:-1, :-2]) * 0.5     # (H, W)
    gy = (xp[2:, 1:-1] - xp[:-2, 1:-1]) * 0.5     # (H, W)
    mag = jnp.sqrt(gx * gx + gy * gy)
    ch, cw = h // CELL, w // CELL

    def cells(a):
        return a.reshape(ch, CELL, cw, CELL)

    f0 = jnp.mean(jnp.abs(cells(gx)), axis=(1, 3))
    f1 = jnp.mean(jnp.abs(cells(gy)), axis=(1, 3))
    f2 = jnp.mean(cells(mag), axis=(1, 3))
    f3 = jnp.max(cells(mag), axis=(1, 3))
    o_ref[0] = jnp.stack([f0, f1, f2, f3], axis=-1).astype(o_ref.dtype)


def feature_extract_pallas(x: jax.Array) -> jax.Array:
    """Gradient-energy descriptors for a batch of grayscale images.

    x: (B, H, W) float32 with H, W divisible by 8.
    Returns (B, H/8, W/8, 4) float32.
    """
    b, h, w = x.shape
    assert h % CELL == 0 and w % CELL == 0, f"H,W must be multiples of {CELL}"
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1)), mode="edge")
    kern = functools.partial(_feature_kernel, h=h, w=w)
    return pl.pallas_call(
        kern,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, h + 2, w + 2), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec(
            (1, h // CELL, w // CELL, FEATS), lambda i: (i, 0, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct(
            (b, h // CELL, w // CELL, FEATS), jnp.float32
        ),
        interpret=True,
    )(xp)
