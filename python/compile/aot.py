"""AOT lowering: JAX graphs -> HLO *text* artifacts + manifest.json.

This is the only place Python touches the build. Each entry point in
model.py is jitted, lowered to StableHLO, converted to an XlaComputation
and dumped as HLO text into artifacts/. The Rust runtime
(rust/src/runtime) loads the text with HloModuleProto::from_text_file,
compiles it on the PJRT CPU client and executes it on the request path.

HLO TEXT, never .serialize(): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 rejects (proto.id() <= INT_MAX);
the text parser reassigns ids and round-trips cleanly. Lowering uses
return_tuple=True, so every artifact returns one tuple that the Rust side
unpacks with Literal::to_tuple().

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32
I32 = jnp.int32


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dtype_tag(dtype) -> str:
    return {jnp.float32.dtype: "f32", jnp.int32.dtype: "s32"}[jnp.dtype(dtype)]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _param_specs():
    return [_spec(shape) for _, shape in model.PARAM_SPECS]


def _named_params():
    return [(name, list(shape), "f32") for name, shape in model.PARAM_SPECS]


def artifact_table():
    """name -> (callable, input ShapeDtypeStructs, named input descriptors,
    named output descriptors). Shapes here are the frozen AOT variants the
    Rust services execute; one compiled executable per variant."""
    table = {}

    def add(name, fn, specs, in_desc, out_desc):
        table[name] = (fn, specs, in_desc, out_desc)

    # --- training service -------------------------------------------------
    b = 16
    add(
        "cnn_train_b16",
        model.cnn_train_step,
        _param_specs() + [_spec((b, model.IMG, model.IMG, 3)), _spec((b,), I32)],
        _named_params()
        + [("x", [b, model.IMG, model.IMG, 3], "f32"), ("y", [b], "s32")],
        [("loss", [], "f32")]
        + [(f"g_{n}", list(s), "f32") for n, s in model.PARAM_SPECS],
    )
    for b in (1, 8, 32):
        add(
            f"cnn_infer_b{b}",
            model.cnn_infer,
            _param_specs() + [_spec((b, model.IMG, model.IMG, 3))],
            _named_params() + [("x", [b, model.IMG, model.IMG, 3], "f32")],
            [("logits", [b, model.NUM_CLASSES], "f32")],
        )

    # --- HD map generation service ----------------------------------------
    for n in (1024, 4096):
        add(
            f"icp_step_{n}",
            model.icp_step,
            [_spec((n, 3)), _spec((n, 3))],
            [("src", [n, 3], "f32"), ("dst", [n, 3], "f32")],
            [
                ("cross_cov", [3, 3], "f32"),
                ("src_centroid", [3], "f32"),
                ("nn_centroid", [3], "f32"),
                ("mean_sq_err", [], "f32"),
            ],
        )

    # --- simulation service (Fig 6 workload) -------------------------------
    for b in (1, 8):
        add(
            f"feature_b{b}",
            model.feature_batch,
            [_spec((b, 64, 64))],
            [("x", [b, 64, 64], "f32")],
            [("features", [b, 8, 8, 4], "f32")],
        )

    return table


def build(out_dir: str, only: list[str] | None = None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text/v1", "artifacts": []}
    for name, (fn, specs, in_desc, out_desc) in artifact_table().items():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": fname,
                "inputs": [
                    {"name": n, "shape": s, "dtype": d} for n, s, d in in_desc
                ],
                "outputs": [
                    {"name": n, "shape": s, "dtype": d} for n, s, d in out_desc
                ],
            }
        )
        print(f"  lowered {name:>16} -> {fname} ({len(text)} chars)")
    manifest["param_order"] = [n for n, _ in model.PARAM_SPECS]
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of artifact names to build")
    # kept for Makefile compatibility: --out some/file.hlo.txt builds
    # everything into that file's directory.
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    build(out_dir or ".", args.only)


if __name__ == "__main__":
    main()
