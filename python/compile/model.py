"""Layer-2 JAX compute graphs for the adcloud platform (build-time only).

Three graph families, each composed from the Layer-1 Pallas kernels and
AOT-lowered by aot.py into HLO-text artifacts the Rust coordinator
executes through PJRT:

  * cnn_*       -- the perception CNN of the training service (section 4):
                   forward inference and the full fwd+bwd train step.
  * icp_step    -- one ICP alignment iteration for HD map generation
                   (section 5.2): Pallas correspondence search + centroid /
                   cross-covariance reduction. The tiny 3x3 polar solve is
                   done on the Rust side (the xla_extension 0.5.1 CPU
                   runtime lacks the LAPACK custom-calls SVD would emit).
  * feature_*   -- the image-feature-extraction workload of the simulation
                   service (section 3.3, Fig 6).

Every public function also has a ``use_pallas=False`` escape hatch that
swaps in the pure-jnp oracle, which the pytest suite uses to cross-check
gradients end to end.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import (
    conv2d,
    icp_correspondences_pallas,
    feature_extract_pallas,
)
from .kernels.ref import (
    conv2d_ref,
    icp_correspondences_ref,
    feature_extract_ref,
)

# ---------------------------------------------------------------------------
# Perception CNN (training service, section 4)
# ---------------------------------------------------------------------------

IMG = 32          # input images are IMG x IMG x 3
NUM_CLASSES = 10

# (name, shape) in the exact order the Rust side feeds parameter literals.
PARAM_SPECS: list[tuple[str, tuple[int, ...]]] = [
    ("c1w", (3, 3, 3, 8)),
    ("c1b", (8,)),
    ("c2w", (3, 3, 8, 16)),
    ("c2b", (16,)),
    ("dw", (16 * (IMG // 4) * (IMG // 4), NUM_CLASSES)),
    ("db", (NUM_CLASSES,)),
]


def init_params(key: jax.Array) -> list[jax.Array]:
    """He-scaled initialisation matching PARAM_SPECS order."""
    params = []
    for name, shape in PARAM_SPECS:
        key, sub = jax.random.split(key)
        if name.endswith("b"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            scale = jnp.sqrt(2.0 / fan_in)
            params.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return params


def _maxpool2(x: jax.Array) -> jax.Array:
    """2x2 max pooling, NHWC."""
    b, h, w, c = x.shape
    return jnp.max(x.reshape(b, h // 2, 2, w // 2, 2, c), axis=(2, 4))


def cnn_forward(params: list[jax.Array], x: jax.Array,
                use_pallas: bool = True) -> jax.Array:
    """Logits for a batch of (B, 32, 32, 3) images."""
    conv = conv2d if use_pallas else conv2d_ref
    c1w, c1b, c2w, c2b, dw, db = params
    h = jax.nn.relu(conv(x, c1w) + c1b)
    h = _maxpool2(h)                      # (B, 16, 16, 8)
    h = jax.nn.relu(conv(h, c2w) + c2b)
    h = _maxpool2(h)                      # (B, 8, 8, 16)
    h = h.reshape(h.shape[0], -1)         # (B, 1024)
    return h @ dw + db


def cnn_loss(params: list[jax.Array], x: jax.Array, y: jax.Array,
             use_pallas: bool = True) -> jax.Array:
    """Mean softmax cross-entropy; y is int32 class labels (B,)."""
    logits = cnn_forward(params, x, use_pallas=use_pallas)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, y[:, None].astype(jnp.int32),
                                 axis=-1)[:, 0]
    return jnp.mean(logz - picked)


def cnn_train_step(*args, use_pallas: bool = True):
    """(c1w, c1b, c2w, c2b, dw, db, x, y) -> (loss, *grads).

    Flat-argument signature so the AOT artifact takes each parameter as a
    separate PJRT input literal and returns a flat tuple.
    """
    params = list(args[:6])
    x, y = args[6], args[7]
    loss, grads = jax.value_and_grad(
        lambda p: cnn_loss(p, x, y, use_pallas=use_pallas)
    )(params)
    return (loss, *grads)


def cnn_infer(*args, use_pallas: bool = True) -> tuple[jax.Array]:
    """(c1w, c1b, c2w, c2b, dw, db, x) -> (logits,)."""
    return (cnn_forward(list(args[:6]), args[6], use_pallas=use_pallas),)


# ---------------------------------------------------------------------------
# ICP alignment step (HD map generation, section 5.2)
# ---------------------------------------------------------------------------


def icp_step(src: jax.Array, dst: jax.Array, use_pallas: bool = True):
    """One ICP data pass: correspondences + alignment statistics.

    src, dst: (N, 3) / (M, 3) float32 clouds.
    Returns (cross_cov (3,3), src_centroid (3,), nn_centroid (3,),
             mean_sq_err ()). Rust recovers R, t from cross_cov via a
    3x3 Jacobi polar decomposition and applies/iterates.
    """
    corr = (icp_correspondences_pallas if use_pallas
            else icp_correspondences_ref)
    nearest, d2 = corr(src, dst)
    cs = jnp.mean(src, axis=0)
    cd = jnp.mean(nearest, axis=0)
    sc = src - cs
    dc = nearest - cd
    # Cross-covariance H = sum_i sc_i dc_i^T ; R = polar(H) on the Rust side.
    h = sc.T @ dc
    return h, cs, cd, jnp.mean(d2)


# ---------------------------------------------------------------------------
# Feature extraction (simulation service, section 3.3 / Fig 6)
# ---------------------------------------------------------------------------


def feature_batch(x: jax.Array, use_pallas: bool = True) -> tuple[jax.Array]:
    """(B, H, W) grayscale -> (B, H/8, W/8, 4) descriptors."""
    fn = feature_extract_pallas if use_pallas else feature_extract_ref
    return (fn(x),)
