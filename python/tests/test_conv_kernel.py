"""Pallas conv2d kernel vs pure-jnp oracle (hypothesis shape sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d_pallas, conv2d
from compile.kernels.ref import conv2d_ref


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(4, 12),
    w=st.integers(4, 12),
    cin=st.integers(1, 6),
    cout=st.integers(1, 6),
    k=st.sampled_from([1, 3, 5]),
)
def test_conv2d_matches_ref_swept(b, h, w, cin, cout, k):
    x = _rand(0, (b, h, w, cin))
    wgt = _rand(1, (k, k, cin, cout))
    got = conv2d_pallas(x, wgt)
    want = conv2d_ref(x, wgt)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("shape", [(1, 8, 8, 3), (2, 16, 16, 8), (4, 32, 32, 3)])
def test_conv2d_service_shapes(shape):
    """The exact shapes the AOT artifacts freeze."""
    x = _rand(2, shape)
    wgt = _rand(3, (3, 3, shape[-1], 8))
    np.testing.assert_allclose(
        conv2d_pallas(x, wgt), conv2d_ref(x, wgt), rtol=1e-4, atol=1e-5
    )


def test_conv2d_even_kernel_padding():
    """SAME padding with an even kernel uses the asymmetric split."""
    x = _rand(4, (1, 6, 6, 2))
    wgt = _rand(5, (2, 2, 2, 3))
    np.testing.assert_allclose(
        conv2d_pallas(x, wgt), conv2d_ref(x, wgt), rtol=1e-4, atol=1e-5
    )


def test_conv2d_identity_kernel():
    """1x1 identity filter reproduces the input."""
    x = _rand(6, (2, 5, 7, 3))
    eye = jnp.eye(3, dtype=jnp.float32).reshape(1, 1, 3, 3)
    np.testing.assert_allclose(conv2d_pallas(x, eye), x, rtol=1e-5, atol=1e-6)


def test_conv2d_zero_input():
    x = jnp.zeros((1, 8, 8, 3))
    wgt = _rand(7, (3, 3, 3, 4))
    assert float(jnp.abs(conv2d_pallas(x, wgt)).max()) == 0.0


def test_conv2d_grad_matches_ref():
    """custom_vjp backward (Pallas dx + einsum dw) == autodiff of oracle."""
    x = _rand(8, (2, 8, 8, 3))
    wgt = _rand(9, (3, 3, 3, 4))

    def loss_pallas(x, w):
        return jnp.sum(jnp.tanh(conv2d(x, w)))

    def loss_ref(x, w):
        return jnp.sum(jnp.tanh(conv2d_ref(x, w)))

    gx_p, gw_p = jax.grad(loss_pallas, argnums=(0, 1))(x, wgt)
    gx_r, gw_r = jax.grad(loss_ref, argnums=(0, 1))(x, wgt)
    np.testing.assert_allclose(gx_p, gx_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gw_p, gw_r, rtol=1e-4, atol=1e-5)


def test_conv2d_channel_mismatch_raises():
    x = _rand(10, (1, 4, 4, 3))
    wgt = _rand(11, (3, 3, 5, 2))
    with pytest.raises(AssertionError):
        conv2d_pallas(x, wgt)
