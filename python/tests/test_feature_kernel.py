"""Pallas feature-extraction kernel vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import feature_extract_pallas
from compile.kernels.ref import feature_extract_ref


def _img(key, b, h, w):
    return jax.random.uniform(jax.random.PRNGKey(key), (b, h, w))


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 4),
    hc=st.integers(1, 6),
    wc=st.integers(1, 6),
    seed=st.integers(0, 2**16),
)
def test_feature_matches_ref_swept(b, hc, wc, seed):
    x = _img(seed, b, hc * 8, wc * 8)
    np.testing.assert_allclose(
        feature_extract_pallas(x),
        feature_extract_ref(x),
        rtol=1e-4,
        atol=1e-5,
    )


def test_feature_service_shape():
    """The AOT artifact's frozen 64x64 shape."""
    x = _img(1, 8, 64, 64)
    got = feature_extract_pallas(x)
    assert got.shape == (8, 8, 8, 4)
    np.testing.assert_allclose(
        got, feature_extract_ref(x), rtol=1e-4, atol=1e-5
    )


def test_feature_constant_image_zero_gradients():
    x = jnp.full((1, 16, 16), 0.7)
    got = feature_extract_pallas(x)
    np.testing.assert_allclose(got, jnp.zeros_like(got), atol=1e-6)


def test_feature_vertical_edge_detected():
    """A vertical step edge shows up in |gx| but not |gy|."""
    x = jnp.concatenate(
        [jnp.zeros((1, 16, 8)), jnp.ones((1, 16, 8))], axis=2
    )
    f = feature_extract_pallas(x)
    assert float(f[..., 0].max()) > 0.0     # mean |gx| sees the edge
    np.testing.assert_allclose(f[..., 1], jnp.zeros_like(f[..., 1]), atol=1e-6)


def test_feature_rejects_bad_cell_multiple():
    with pytest.raises(AssertionError):
        feature_extract_pallas(_img(2, 1, 12, 16))
