"""Pallas ICP correspondence kernel vs brute-force oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import icp_correspondences_pallas
from compile.kernels.ref import icp_correspondences_ref


def _cloud(key, n, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), (n, 3))


@settings(max_examples=10, deadline=None)
@given(
    nb=st.integers(1, 4),
    block=st.sampled_from([8, 32, 64]),
    m=st.integers(3, 200),
    seed=st.integers(0, 2**16),
)
def test_icp_matches_ref_swept(nb, block, m, seed):
    src = _cloud(seed, nb * block)
    dst = _cloud(seed + 1, m)
    near_p, d2_p = icp_correspondences_pallas(src, dst, block_n=block)
    near_r, d2_r = icp_correspondences_ref(src, dst)
    np.testing.assert_allclose(d2_p, d2_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(near_p, near_r, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n", [1024, 4096])
def test_icp_service_shapes(n):
    """Exact AOT artifact shapes."""
    src = _cloud(0, n, scale=5.0)
    dst = _cloud(1, n, scale=5.0)
    near_p, d2_p = icp_correspondences_pallas(src, dst)
    near_r, d2_r = icp_correspondences_ref(src, dst)
    np.testing.assert_allclose(d2_p, d2_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(near_p, near_r, rtol=1e-5, atol=1e-5)


def test_icp_identical_clouds_zero_distance():
    src = _cloud(2, 128)
    near, d2 = icp_correspondences_pallas(src, src, block_n=64)
    np.testing.assert_allclose(near, src, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(d2, jnp.zeros(128), atol=1e-5)


def test_icp_single_destination_point():
    """Every source point maps to the lone destination point."""
    src = _cloud(3, 64)
    dst = jnp.array([[1.0, 2.0, 3.0]])
    near, d2 = icp_correspondences_pallas(src, dst, block_n=64)
    np.testing.assert_allclose(near, jnp.broadcast_to(dst, (64, 3)))
    np.testing.assert_allclose(
        d2, jnp.sum((src - dst) ** 2, axis=1), rtol=1e-4, atol=1e-5
    )


def test_icp_distances_nonnegative():
    """The fused max(., 0) clamp kills fp cancellation noise."""
    src = _cloud(4, 256, scale=100.0)
    near, d2 = icp_correspondences_pallas(src, src + 1e-4, block_n=128)
    assert float(d2.min()) >= 0.0


def test_icp_rejects_indivisible_block():
    with pytest.raises(AssertionError):
        icp_correspondences_pallas(_cloud(5, 100), _cloud(6, 10), block_n=64)
