"""AOT pipeline tests: lowering, manifest integrity, HLO-text format."""

import json
import os

import pytest

from compile import aot, model


def test_artifact_table_complete():
    table = aot.artifact_table()
    # Every service the Rust coordinator expects must have its artifact.
    for name in [
        "cnn_train_b16",
        "cnn_infer_b1",
        "cnn_infer_b8",
        "cnn_infer_b32",
        "icp_step_1024",
        "icp_step_4096",
        "feature_b1",
        "feature_b8",
    ]:
        assert name in table, f"missing artifact {name}"


def test_train_artifact_io_descriptors():
    _, specs, in_desc, out_desc = aot.artifact_table()["cnn_train_b16"]
    assert len(specs) == len(in_desc) == len(model.PARAM_SPECS) + 2
    # params first, in PARAM_SPECS order, then x, then y
    for (n, s, d), (pn, ps) in zip(in_desc, model.PARAM_SPECS):
        assert n == pn and tuple(s) == ps and d == "f32"
    assert in_desc[-1] == ("y", [16], "s32")
    assert out_desc[0] == ("loss", [], "f32")
    assert len(out_desc) == 1 + len(model.PARAM_SPECS)


@pytest.mark.parametrize("name", ["feature_b1", "icp_step_1024"])
def test_lower_to_hlo_text(tmp_path, name):
    manifest = aot.build(str(tmp_path), only=[name])
    (entry,) = manifest["artifacts"]
    assert entry["name"] == name
    text = (tmp_path / entry["file"]).read_text()
    assert text.startswith("HloModule"), text[:60]
    # return_tuple=True means the root is a tuple.
    assert "ROOT" in text
    data = json.loads((tmp_path / "manifest.json").read_text())
    assert data["format"] == "hlo-text/v1"
    assert data["param_order"] == [n for n, _ in model.PARAM_SPECS]


def test_built_artifacts_exist_if_make_ran():
    """When artifacts/ exists (make artifacts), it must be complete."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    if not os.path.isdir(art):
        pytest.skip("artifacts not built yet")
    data = json.load(open(os.path.join(art, "manifest.json")))
    for entry in data["artifacts"]:
        path = os.path.join(art, entry["file"])
        assert os.path.isfile(path), f"missing {entry['file']}"
        with open(path) as f:
            assert f.read(9) == "HloModule"
