"""L2 graph tests: shapes, gradient cross-checks, training sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def batch():
    kx, ky = jax.random.split(jax.random.PRNGKey(7))
    x = jax.random.normal(kx, (16, model.IMG, model.IMG, 3))
    y = jax.random.randint(ky, (16,), 0, model.NUM_CLASSES)
    return x, y


def test_param_specs_match_init(params):
    assert len(params) == len(model.PARAM_SPECS)
    for p, (_, shape) in zip(params, model.PARAM_SPECS):
        assert p.shape == shape
        assert p.dtype == jnp.float32


def test_forward_shapes(params, batch):
    x, _ = batch
    logits = model.cnn_forward(params, x)
    assert logits.shape == (16, model.NUM_CLASSES)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_train_step_grads_match_reference(params, batch):
    """Full fwd+bwd through the Pallas conv == autodiff of oracle model."""
    x, y = batch
    out_p = model.cnn_train_step(*params, x, y, use_pallas=True)
    out_r = model.cnn_train_step(*params, x, y, use_pallas=False)
    assert len(out_p) == 1 + len(params)
    for a, b in zip(out_p, out_r):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-5)


def test_initial_loss_near_uniform(params, batch):
    """Fresh params should be near -log(1/C)."""
    x, y = batch
    loss = model.cnn_loss(params, x, y)
    assert abs(float(loss) - np.log(model.NUM_CLASSES)) < 6.0


def test_sgd_reduces_loss(params, batch):
    """A few SGD steps on one batch must overfit it measurably."""
    x, y = batch
    p = [jnp.array(q) for q in params]
    first = None
    lr = 0.05
    for _ in range(12):
        out = model.cnn_train_step(*p, x, y, use_pallas=False)
        loss, grads = out[0], out[1:]
        if first is None:
            first = float(loss)
        p = [q - lr * g for q, g in zip(p, grads)]
    final = float(model.cnn_loss(p, x, y, use_pallas=False))
    assert final < first * 0.8, (first, final)


def test_infer_matches_forward(params, batch):
    x, _ = batch
    (logits,) = model.cnn_infer(*params, x[:8])
    np.testing.assert_allclose(
        logits, model.cnn_forward(params, x[:8]), rtol=1e-5, atol=1e-6
    )


def test_icp_step_recovers_translation():
    """For a pure small translation the step statistics solve it exactly."""
    key = jax.random.PRNGKey(3)
    src = jax.random.normal(key, (256, 3))
    t = jnp.array([0.05, -0.02, 0.03])
    dst = src + t
    h, cs, cd, err = model.icp_step(src, dst, use_pallas=True)
    # With a dense-enough cloud and a tiny offset, nearest(src_i) == dst_i,
    # so the centroid difference IS the translation.
    np.testing.assert_allclose(cd - cs, t, atol=5e-3)
    assert float(err) < 0.02
    # Cross-covariance of a pure translation is ~diagonal-dominant PSD-ish;
    # at minimum it must be finite and symmetric-ish in magnitude.
    assert bool(jnp.all(jnp.isfinite(h)))


def test_icp_step_pallas_matches_ref():
    k1, k2 = jax.random.split(jax.random.PRNGKey(9))
    src = jax.random.normal(k1, (512, 3))
    dst = jax.random.normal(k2, (512, 3))
    out_p = model.icp_step(src, dst, use_pallas=True)
    out_r = model.icp_step(src, dst, use_pallas=False)
    for a, b in zip(out_p, out_r):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_feature_batch_matches_ref():
    x = jax.random.uniform(jax.random.PRNGKey(11), (4, 64, 64))
    (got,) = model.feature_batch(x, use_pallas=True)
    (want,) = model.feature_batch(x, use_pallas=False)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
